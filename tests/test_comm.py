"""Wire-format comm subsystem: codec properties, error feedback, the
channel's uplink/downlink contracts, engine equivalence under
codec="none", systime encoded-byte pricing, and lossy-but-learning e2e.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.preresnet20 import reduced as rn_reduced
from repro.fl.comm import (CODECS, CommChannel, ErrorFeedback,
                           WireUpdate, get_codec)
from repro.fl.data import build_federated
from repro.fl.engine import (RoundEngine, SimConfig, build_context,
                             default_batch_fn)
from repro.fl.registry import get_strategy
from repro.fl.sampling import SequentialScheduler, UniformSampler
from repro.fl.strategy import tree_bytes, wire_bytes
from repro.fl.systime import (DEVICE_TIERS, AsyncEngine, SystemModel,
                              uniform_profiles)

CFG = rn_reduced(num_classes=10, image_size=16)


def _data(n=8, seed=0):
    return build_federated(num_clients=n, alpha=1.0, n_train=40 * n,
                           n_test=160, image_size=16, seed=seed)


def _sim(**kw):
    base = dict(rounds=2, participation=0.5, lr=0.05, local_steps=1,
                batch_size=32, scenario="fair", seed=0)
    base.update(kw)
    return SimConfig(**base)


def _tree(seed=0, shapes=((7, 3), (11,))):
    rng = np.random.default_rng(seed)
    return {f"l{i}": jnp.asarray(rng.normal(size=s).astype(np.float32))
            for i, s in enumerate(shapes)}


def _maxdiff(a, b):
    return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ----------------------------------------------------------------- registry
def test_codec_registry():
    assert set(CODECS) >= {"none", "fp16", "qsgd_int8", "topk"}
    with pytest.raises(KeyError, match="unknown codec"):
        get_codec("gzip")
    inst = get_codec("topk")
    assert get_codec(inst) is inst            # instance passthrough
    assert get_codec(None).name == "none"


# ------------------------------------------------------------- codec props
def test_none_codec_bitwise_identity_and_bytes():
    t = _tree()
    c = get_codec("none")
    wp = c.encode(t)
    dec = c.decode(wp)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(dec)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert wp.nbytes == tree_bytes(t) == c.size_bytes(t)


def test_fp16_codec_within_half_eps():
    t = _tree(1)
    c = get_codec("fp16")
    wp = c.encode(t)
    dec = c.decode(wp)
    assert wp.nbytes == tree_bytes(t) // 2
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(dec)):
        a, b = np.asarray(a), np.asarray(b)
        assert np.all(np.abs(a - b) <= np.abs(a) * 2.0 ** -10 + 1e-7)


def test_qsgd_int8_unbiased_over_seeds():
    x = np.random.default_rng(3).normal(size=40).astype(np.float32)
    t = {"w": jnp.asarray(x)}
    n_seeds = 400
    acc = np.zeros_like(x)
    for s in range(n_seeds):
        c = get_codec("qsgd_int8")
        c._rng = np.random.default_rng(s)
        acc += np.asarray(c.decode(c.encode(t))["w"])
    scale = np.abs(x).max() / 127.0
    # per-coordinate mean within a few standard errors of the truth
    assert np.abs(acc / n_seeds - x).max() < 5 * scale / np.sqrt(n_seeds) \
        + 1e-6


def test_qsgd_bytes_one_per_coord_plus_scale():
    t = _tree(2)
    c = get_codec("qsgd_int8")
    n = sum(np.asarray(v).size for v in t.values())
    assert c.encode(t).nbytes == n + 4 * len(t) == c.size_bytes(t)


def test_topk_keeps_k_largest_magnitudes():
    rng = np.random.default_rng(4)
    x = rng.permutation(np.linspace(-8.0, 8.0, 40)).astype(np.float32)
    t = {"w": jnp.asarray(x)}
    c = get_codec("topk")            # k_frac=0.1 -> k=4
    wp = c.encode(t)
    dec = np.asarray(c.decode(wp)["w"])
    kept = np.flatnonzero(dec)
    want = np.sort(np.argsort(np.abs(x))[-4:])
    assert np.array_equal(np.sort(kept), want)
    np.testing.assert_allclose(dec[kept], x[kept])
    assert wp.nbytes == 4 * 8       # (fp32 value + i32 index) per kept


def test_masked_encode_prices_only_the_slice():
    t = _tree(5, shapes=((6, 4),))
    mask = {"l0": jnp.zeros((6, 4)).at[:2].set(1.0)}
    for name, per_coord in (("none", 4), ("fp16", 2)):
        c = get_codec(name)
        wp = c.encode(t, mask=mask)
        assert wp.nbytes == 8 * per_coord      # 8 active coords
        dec = np.asarray(c.decode(wp)["l0"])
        assert np.all(dec[2:] == 0.0)          # outside the mask: zero
    c = get_codec("topk")
    dec = np.asarray(c.decode(c.encode(t, mask=mask))["l0"])
    assert np.all(dec[2:] == 0.0)              # top-k never leaves the mask


def test_wire_bytes_helper_unifies_accounting():
    t = _tree(6)
    assert wire_bytes(t) == tree_bytes(t)
    assert wire_bytes(n_coords=10) == 40
    assert wire_bytes(t, codec="fp16") == tree_bytes(t) // 2
    assert wire_bytes(n_coords=100, codec="qsgd_int8") == 104


# ---------------------------------------------------------- error feedback
def test_error_feedback_transmits_everything_eventually():
    """EF-SGD invariant: for a constant update the time-averaged decoded
    signal converges to the truth even under a 10%-topk codec."""
    codec, ef = get_codec("topk"), ErrorFeedback()
    x = _tree(7, shapes=((16,),))
    total = np.zeros(16, np.float32)
    steps = 60
    for _ in range(steps):
        corrected = ef.correct(0, x)
        wp = codec.encode(corrected)
        dec = codec.decode(wp)
        ef.update(0, corrected, dec)
        total += np.asarray(dec["l0"])
    err = np.abs(total / steps - np.asarray(x["l0"])).max()
    assert err < 0.15 * float(np.abs(np.asarray(x["l0"])).max())
    # and the residual stays bounded
    res = ef.residual(0)
    assert float(np.abs(res["l0"]).max()) < 10 * float(
        np.abs(np.asarray(x["l0"])).max())


def test_error_feedback_resets_on_structure_change():
    ef = ErrorFeedback()
    a = {"w": jnp.ones((3,))}
    ef.update(0, a, {"w": jnp.zeros((3,))})
    assert ef.residual(0) is not None
    b = {"v": jnp.ones((5,))}
    out = ef.correct(0, b)                    # mismatch: drop, no crash
    assert out is b and ef.residual(0) is None


def test_error_feedback_tag_distinguishes_same_shape_wires():
    """Two same-capacity SplitMix subsets share treedef AND shapes —
    only the wire tag tells the coordinate sets apart.  A residual must
    never cross tags (it would correct the wrong base net)."""
    ef = ErrorFeedback()
    delta = [{"w": jnp.full((4,), 9.0)}]
    ef.update(0, delta, [{"w": jnp.zeros((4,))}], tag=(0, 1))
    # same client, same structure, different base subset -> reset
    out = ef.correct(0, delta, tag=(1, 2))
    assert np.allclose(np.asarray(out[0]["w"]), 9.0)
    assert ef.residual(0) is None
    # matching tag -> residual applies
    ef.update(0, delta, [{"w": jnp.zeros((4,))}], tag=(0, 1))
    out = ef.correct(0, delta, tag=(0, 1))
    assert np.allclose(np.asarray(out[0]["w"]), 18.0)


def test_error_feedback_keeps_nonfloat_leaves_congruent():
    """A wire tree with a non-float array leaf must not break residual
    congruence (a scalar placeholder would reset EF every round)."""
    codec, ef = get_codec("topk"), ErrorFeedback()
    tree = {"w": jnp.ones((8,), jnp.float32),
            "ids": jnp.arange(4, dtype=jnp.int32)}
    for _ in range(2):
        corrected = ef.correct(0, tree)
        wp = codec.encode(corrected)
        ef.update(0, corrected, codec.decode(wp))
    # second round found a congruent residual and kept accumulating
    assert ef.residual(0) is not None
    assert float(np.abs(ef.residual(0)["w"]).sum()) > 0


def test_splitmix_full_downlink_prices_the_base_subset():
    """SplitMixState is not a pytree; "full" mode must fall back to the
    downlink hook instead of pricing the broadcast as 0 bytes."""
    data, sim = _data(), _sim()
    ctx = build_context(data, sim, model_cfg=CFG)
    strat = get_strategy("splitmix")
    state = strat.init_state(ctx)
    chan = CommChannel("none", downlink="full")
    b = chan.downlink_bytes(strat, ctx, state, 0)
    assert b == tree_bytes(strat.downlink_tree(ctx, state, 0)) > 0


def test_splitmix_wire_tag_is_the_base_subset():
    """splitmix's wire_parts tags the wire with the trained base ids, so
    rotating subsets reset EF instead of cross-correcting networks."""
    from repro.fl.strategy import ClientResult
    data, sim = _data(), _sim()
    ctx = build_context(data, sim, model_cfg=CFG)
    strat = get_strategy("splitmix")
    state = strat.init_state(ctx)
    res = strat.client_update(ctx, state, 0,
                              [data.client_batch(0, 32, ctx.rng)])
    spec = strat.wire_parts(ctx, state, res)
    assert spec.tag == tuple(i for i, _ in res.payload)
    # channel round-trips the payload shape (idx, tree) intact
    chan = CommChannel("fp16")
    enc = chan.encode_result(strat, ctx, state, 0, res)
    dec = chan.decode_result(enc)
    assert [i for i, _ in dec.payload] == list(spec.tag)


# ----------------------------------------------------------------- channel
def test_none_channel_is_a_strict_noop():
    from repro.fl.strategy import ClientResult
    chan = CommChannel("none")
    res = ClientResult({"w": jnp.ones((3,))}, 1.0)
    payload = res.payload
    out = chan.encode_result(object(), None, None, 0, res)
    assert out is res and out.payload is payload and out.comm_bytes is None


def test_channel_roundtrip_sets_encoded_bytes_and_decodes():
    from repro.fl.strategy import ClientResult
    chan = CommChannel("fp16")
    state = _tree(8)
    local = jax.tree.map(lambda x: x + 0.25, state)
    res = ClientResult(local, 1.0)
    res = chan.encode_result(object(), None, state, 0, res)
    assert isinstance(res.payload, WireUpdate)
    assert res.comm_bytes == tree_bytes(state) // 2
    res = chan.decode_result(res)
    # fp16 on the DELTA (0.25 everywhere) is near-exact after re-adding
    assert _maxdiff(res.payload, local) < 1e-3


def test_downlink_modes_validate_and_order():
    with pytest.raises(ValueError, match="downlink"):
        CommChannel("none", downlink="trickle")
    chan_delta = CommChannel("none", downlink="delta")
    state = _tree(9)
    first = chan_delta.downlink_bytes(object(), None, state, 0)
    assert first == tree_bytes(state)          # first contact: dense
    again = chan_delta.downlink_bytes(object(), None, state, 0)
    assert again == 0                          # nothing changed
    state2 = dict(state)
    state2["l0"] = state["l0"] + jnp.zeros_like(state["l0"]).at[0, 0].set(1.)
    third = chan_delta.downlink_bytes(object(), None, state2, 0)
    assert 0 < third <= 8 * 1 + 0 + 1          # one changed coordinate


# ------------------------------------------------- engine equivalence (crit.)
@pytest.mark.parametrize("method", ["fedavg", "fedepth"])
def test_codec_none_reproduces_channel_free_loop(method):
    """Acceptance criterion: RoundEngine(codec="none") is bitwise the
    pre-channel engine — same seeded history, same final params as a
    hand-rolled sample->update->aggregate loop."""
    data, sim = _data(), _sim(rounds=3)
    engine = RoundEngine(get_strategy(method),
                         build_context(data, sim, model_cfg=CFG),
                         codec="none")
    state_e, hist = engine.run(eval_every=1)

    ctx = build_context(data, sim, model_cfg=CFG)
    strat = get_strategy(method)
    setup = getattr(strat, "setup", None)
    if setup:
        setup(ctx)
    state = strat.init_state(ctx)
    batch_fn = default_batch_fn(ctx)
    sampler, sched = UniformSampler(), SequentialScheduler()
    ups = []
    for rd in range(sim.rounds):
        cohort = sampler.sample(ctx, rd)
        results = sched.run(ctx, strat, state, cohort, batch_fn)
        ups.append(sum(r.comm_bytes if r.comm_bytes is not None
                       else tree_bytes(r.payload) for r in results))
        state = strat.aggregate(ctx, state, results)
        strat.eval_model(ctx, state, data.x_test, data.y_test)
    assert [h.comm_bytes for h in hist] == ups
    assert _maxdiff(state_e, state) == 0.0


@pytest.mark.parametrize("method", ["fedavg", "fedepth"])
def test_zero_latency_sync_matches_round_engine_with_codec(method):
    """Cross-engine equivalence holds WITH a deterministic lossy codec:
    both engines encode the same sequence, so seeded histories match."""
    data, sim = _data(), _sim(rounds=2)
    _, ref = RoundEngine(get_strategy(method),
                         build_context(data, sim, model_cfg=CFG),
                         codec="fp16", downlink="sliced").run(eval_every=1)
    _, got = AsyncEngine(get_strategy(method),
                         build_context(data, sim, model_cfg=CFG),
                         mode="sync", codec="fp16",
                         downlink="sliced").run(eval_every=1)
    assert [(r.round, r.comm_bytes, r.down_bytes) for r in ref] \
        == [(g.round, g.comm_bytes, g.down_bytes) for g in got]
    np.testing.assert_allclose([r.accuracy for r in ref],
                               [g.accuracy for g in got], atol=1e-6)


def test_lossy_codec_halves_uplink_and_stays_close():
    data, sim = _data(), _sim(rounds=1)
    hists = {}
    for codec in ("none", "fp16"):
        eng = RoundEngine(get_strategy("fedavg"),
                          build_context(data, sim, model_cfg=CFG),
                          codec=codec)
        state, hist = eng.run(eval_every=1)
        hists[codec] = (state, hist[-1])
    assert hists["fp16"][1].comm_bytes * 2 == hists["none"][1].comm_bytes
    assert _maxdiff(hists["fp16"][0], hists["none"][0]) < 1e-2


# ----------------------------------------------------- downlink accounting
def test_heterofl_sliced_downlink_and_wire_accounting():
    data, sim = _data(), _sim(rounds=1, participation=1.0)
    full = RoundEngine(get_strategy("heterofl"),
                       build_context(data, sim, model_cfg=CFG),
                       downlink="full").run(eval_every=1)[1][-1]
    sliced = RoundEngine(get_strategy("heterofl"),
                         build_context(data, sim, model_cfg=CFG),
                         downlink="sliced").run(eval_every=1)[1][-1]
    assert 0 < sliced.down_bytes < full.down_bytes
    # uplink: unchanged by downlink mode, and == slice coords * 4
    assert sliced.comm_bytes == full.comm_bytes > 0


def test_depthfl_depth_slice_shrinks_downlink():
    data = _data()
    sim = _sim(rounds=1, participation=1.0, scenario="lack")
    ctx = build_context(data, sim, model_cfg=CFG)
    strat = get_strategy("depthfl")
    strat.setup(ctx)
    state = strat.init_state(ctx)
    chan = CommChannel("none", downlink="sliced")
    shallow = int(np.argmin(strat.depths))
    deep = int(np.argmax(strat.depths))
    assert strat.depths[shallow] < strat.depths[deep]
    b_shallow = chan.downlink_bytes(strat, ctx, state, shallow)
    b_deep = chan.downlink_bytes(strat, ctx, state, deep)
    assert 0 < b_shallow < b_deep <= tree_bytes(state)


def test_fedepth_downlink_telescopes_to_full_model():
    data, sim = _data(), _sim()
    ctx = build_context(data, sim, model_cfg=CFG)
    strat = get_strategy("fedepth")
    strat.setup(ctx)
    state = strat.init_state(ctx)
    chan = CommChannel("none", downlink="sliced")
    assert chan.downlink_bytes(strat, ctx, state, 0) == tree_bytes(state)


# ------------------------------------------------------- systime pricing
def test_systime_prices_encoded_bytes_both_directions():
    """Acceptance criterion: simulated link seconds track the encoded
    wire sizes — compressing the uplink shrinks sim time by the byte
    ratio on an uplink-bound device."""
    data = _data()
    sims = {}
    for codec in ("none", "fp16"):
        sim = _sim(rounds=1, participation=1.0)
        eng = AsyncEngine(get_strategy("fedavg"),
                          build_context(data, sim, model_cfg=CFG),
                          system=SystemModel(uniform_profiles(
                              8, DEVICE_TIERS["iot"])),
                          mode="sync", codec=codec)
        _, hist = eng.run(eval_every=1)
        sims[codec] = hist[-1]
    none, fp16 = sims["none"], sims["fp16"]
    assert fp16.comm_bytes * 2 == none.comm_bytes
    assert fp16.down_bytes == none.down_bytes      # downlink stays exact
    assert fp16.sim_seconds < none.sim_seconds
    # iot uplink (0.125 MB/s) dominates: halved payloads save close to
    # the full uplink-seconds difference
    prof = DEVICE_TIERS["iot"]
    saved = (none.comm_bytes - fp16.comm_bytes) / 8 / prof.link_up
    assert none.sim_seconds - fp16.sim_seconds \
        == pytest.approx(saved, rel=1e-6)


def test_deadline_miss_rolls_back_error_feedback():
    """A deadline-dropped payload never reached the server, so the
    client's EF residual must revert to its pre-encode value — the
    transmitted mass is retransmitted later, not silently lost."""
    from repro.fl.systime import DeviceProfile, ZERO_LATENCY
    data = _data()
    sim = _sim(rounds=1, participation=1.0)
    slow = DeviceProfile("crawler", flops=float("inf"),
                         mem_bw=float("inf"), link_up=1.0,
                         link_down=float("inf"), mem_bytes=float("inf"))
    profiles = [slow if k < 4 else ZERO_LATENCY for k in range(8)]
    eng = AsyncEngine(get_strategy("fedavg"),
                      build_context(data, sim, model_cfg=CFG),
                      system=SystemModel(profiles), mode="sync",
                      deadline_s=1.0, codec="topk")
    _, _ = eng.run(eval_every=1)
    missed = {t[2] for t in eng.trace if t[0] == "miss"}
    landed = {t[2] for t in eng.trace if t[0] == "finish"}
    assert missed and landed
    ef = eng.channel.ef
    # first-ever encode: pre-encode residual was None, so a miss must
    # leave NO residual; delivered clients keep their codec error
    assert all(ef.residual(k) is None for k in missed)
    assert all(ef.residual(k) is not None for k in landed)


def test_async_mode_runs_with_lossy_codec_and_counts_downlink():
    data, sim = _data(), _sim(rounds=3)
    eng = AsyncEngine(get_strategy("fedavg"),
                      build_context(data, sim, model_cfg=CFG),
                      system=SystemModel(uniform_profiles(
                          8, DEVICE_TIERS["workstation"])),
                      mode="async", concurrency=3, buffer_size=1,
                      codec="qsgd_int8", downlink="delta")
    _, hist = eng.run(eval_every=1)
    assert hist[-1].round == 3
    assert sum(h.comm_bytes for h in hist) > 0
    assert sum(h.down_bytes for h in hist) > 0


# -------------------------------------------------------------- decode path
def test_aggregation_accepts_wire_updates_directly():
    """core.aggregation's decode-at-aggregate path: WireUpdates can go
    straight into fedavg without pre-decoding."""
    from repro.core import aggregation
    state = _tree(10)
    chan = CommChannel("fp16")
    from repro.fl.strategy import ClientResult
    encs = []
    for k in range(3):
        local = jax.tree.map(lambda x, _k=k: x + 0.1 * (_k + 1), state)
        res = chan.encode_result(object(), None, state, k,
                                 ClientResult(local, 1.0))
        encs.append(res.payload)
    assert all(isinstance(e, WireUpdate) for e in encs)
    out = aggregation.fedavg(encs, [1.0, 1.0, 1.0])
    want = jax.tree.map(lambda x: x + 0.2, state)
    assert _maxdiff(out, want) < 1e-3


# ------------------------------------------------------------------- e2e
def test_lossy_uplink_compression_ratio_floor():
    """The topk@0.1 wire is >= 4x smaller than the raw uplink (10x by
    construction: 8 bytes per kept coordinate at k_frac=0.1) — pure byte
    arithmetic, so one round suffices."""
    data, sim = _data(), _sim(rounds=1)
    bytes_for = {}
    for name, codec in (("none", "none"), ("topk", get_codec("topk"))):
        eng = RoundEngine(get_strategy("fedepth"),
                          build_context(data, sim, model_cfg=CFG),
                          codec=codec)
        _, hist = eng.run(eval_every=1)
        bytes_for[name] = sum(h.comm_bytes for h in hist)
    assert bytes_for["none"] / bytes_for["topk"] >= 4.0


def test_fedepth_learns_above_chance_with_lossy_codec_and_ef():
    """Acceptance-adjacent: a ~4x-compressing stochastic int8 uplink
    with error feedback still learns well above chance under fedepth
    (seed-deterministic trajectory: last-3 eval mean 0.25 on this
    config; the tail mean guards against single-round oscillation)."""
    data = build_federated(num_clients=8, alpha=1.0, n_train=640,
                           n_test=200, image_size=16, seed=0)
    sim = _sim(rounds=14, lr=0.08, local_steps=2, batch_size=64)
    eng = RoundEngine(get_strategy("fedepth"),
                      build_context(data, sim, model_cfg=CFG),
                      codec=get_codec("qsgd_int8"))
    _, hist = eng.run(eval_every=2)
    tail = [h.accuracy for h in hist[-3:]]
    assert sum(tail) / len(tail) > 0.15        # chance is 0.10


# -------------------------------------------------- hypothesis properties
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                        # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    settings.register_profile("comm", max_examples=25, deadline=None)
    settings.load_profile("comm")

    @st.composite
    def float_trees(draw):
        n_leaves = draw(st.integers(1, 3))
        rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
        scale = draw(st.floats(1e-3, 1e3))
        return {f"l{i}": jnp.asarray(
            (rng.normal(size=draw(st.integers(1, 40))) * scale
             ).astype(np.float32)) for i in range(n_leaves)}

    @given(float_trees())
    def test_prop_none_identity(tree):
        c = get_codec("none")
        dec = c.decode(c.encode(tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    @given(float_trees())
    def test_prop_fp16_eps_bound(tree):
        c = get_codec("fp16")
        dec = c.decode(c.encode(tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
            a, b = np.asarray(a), np.asarray(b)
            assert np.all(np.abs(a - b) <= np.abs(a) * 2.0 ** -10 + 1e-7)

    @given(float_trees(), st.floats(0.05, 1.0))
    def test_prop_topk_keeps_largest(tree, frac):
        from repro.fl.comm.codecs import TopKCodec
        c = TopKCodec(k_frac=frac)
        dec = c.decode(c.encode(tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
            a, b = np.asarray(a).ravel(), np.asarray(b).ravel()
            kept = np.flatnonzero(b)
            k = max(1, int(np.ceil(frac * a.size)))
            # every kept magnitude >= every dropped magnitude
            dropped = np.setdiff1d(np.arange(a.size), kept)
            assert len(kept) == min(k, a.size)
            if dropped.size and kept.size:
                assert np.abs(a[kept]).min() >= np.abs(a[dropped]).max() \
                    - 1e-12
            np.testing.assert_allclose(b[kept], a[kept])

    @given(st.integers(0, 2 ** 16))
    def test_prop_qsgd_decode_within_one_level(seed):
        rng = np.random.default_rng(seed)
        x = (rng.normal(size=32) * rng.uniform(0.1, 10)).astype(np.float32)
        c = get_codec("qsgd_int8")
        c._rng = np.random.default_rng(seed + 1)
        dec = np.asarray(c.decode(c.encode({"w": jnp.asarray(x)}))["w"])
        scale = np.abs(x).max() / 127.0
        assert np.all(np.abs(dec - x) <= scale * (1 + 1e-5))
