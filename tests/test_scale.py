"""Population-scale subsystem (fl/scale/): sharded execution
equivalence, on-mesh masked aggregation, spill stores, lazy population
traces, streaming history sinks.

The sharded==vectorized bitwise claims need a MULTI-device CPU mesh,
which XLA only grants at backend init (see ``launch.mesh``) — those
assertions run in a fresh subprocess via the ``multi_device_env``
fixture; everything else runs in-process on the default single device
(where ``make_data_mesh`` gives the 1-device mesh the psum-bitwise
contract is stated for).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs.preresnet20 import reduced as rn_reduced
from repro.core import aggregation, blockwise
from repro.fl.data import build_federated
from repro.fl.engine import RoundEngine, SimConfig, build_context
from repro.fl.registry import get_strategy
from repro.fl.sampling import VectorizedScheduler, make_scheduler
from repro.fl.scale import (HashedDutyCycle, InMemoryStore, JsonlHistorySink,
                            Population, PopulationSampler, PrefixedStore,
                            ShardedScheduler, SpillStore, mesh_aggregate_masked,
                            psum_masked_partials)
from repro.fl.scale.population import population_context, population_system
from repro.fl.scale.state_store import dumps, loads
from repro.fl.comm.error_feedback import ErrorFeedback
from repro.fl.comm.payload import CommChannel
from repro.fl.strategy import ClientResult


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ==========================================================================
# sharded scheduler: single-device equivalence + fallbacks (in-process)
# ==========================================================================
def _tiny_run(method, scheduler, *, scenario="fair", codec="none", rounds=1):
    data = build_federated(num_clients=6, alpha=1.0, n_train=180, n_test=60,
                           image_size=16, seed=0)
    cfg = rn_reduced(num_classes=10, image_size=16)
    sim = SimConfig(rounds=rounds, participation=0.5, lr=0.05, local_steps=1,
                    batch_size=32, scenario=scenario, seed=0)
    engine = RoundEngine(get_strategy(method),
                         build_context(data, sim, model_cfg=cfg),
                         scheduler=scheduler, codec=codec)
    return engine.run(eval_every=rounds)


@pytest.mark.parametrize("method,scenario", [("fedavg", "fair"),
                                             ("fedepth", "lack")])
def test_sharded_equals_vectorized_single_device(method, scenario):
    sv, hv = _tiny_run(method, VectorizedScheduler(min_group=1),
                       scenario=scenario)
    ss, hs = _tiny_run(method, ShardedScheduler(min_group=1),
                       scenario=scenario)
    assert _trees_equal(sv, ss)
    assert [r.comm_bytes for r in hv] == [r.comm_bytes for r in hs]


def test_sharded_fused_mesh_bitwise_on_one_device_mesh():
    # the ISSUE contract: psum of (masked-sum, count) partials ==
    # aggregate_masked BITWISE on a 1-device mesh (psum is identity,
    # fold order identical)
    sv, _ = _tiny_run("fedepth", VectorizedScheduler(min_group=1),
                      scenario="lack")
    ss, hs = _tiny_run("fedepth",
                       ShardedScheduler(min_group=1, aggregate="mesh"),
                       scenario="lack")
    assert _trees_equal(sv, ss)
    assert all(r.comm_bytes > 0 for r in hs)


def test_run_fused_ineligible_returns_notimplemented_without_side_effects():
    # probed BEFORE batches are drawn: the shared rng stream must not
    # advance on a fall-through
    data = build_federated(num_clients=6, alpha=1.0, n_train=180, n_test=60,
                           image_size=16, seed=0)
    cfg = rn_reduced(num_classes=10, image_size=16)
    sim = SimConfig(rounds=1, participation=0.5, seed=0)
    ctx = build_context(data, sim, model_cfg=cfg)
    strat = get_strategy("fedavg")      # unmasked -> ineligible
    strat.setup(ctx)
    state = strat.init_state(ctx)
    sched = ShardedScheduler(aggregate="mesh")
    before = ctx.rng.bit_generator.state
    out = sched.run_fused(ctx, strat, state, [0, 1, 2],
                          lambda k: pytest.fail("batch_fn must not run"))
    assert out is NotImplemented
    assert ctx.rng.bit_generator.state == before


def test_sharded_delegates_plain_strategies_to_fallback():
    calls = []

    class Plain:
        def client_update(self, ctx, state, client_id, batches):
            calls.append(client_id)
            return ClientResult(np.zeros(1), 1.0, comm_bytes=0)

    from repro.fl.strategy import Context
    ctx = Context(sim=SimConfig(participation=0.5), num_clients=8,
                  sizes=np.ones(8), rng=np.random.default_rng(0), key=None)
    out = ShardedScheduler().run(ctx, Plain(), None, [3, 1, 2],
                                 lambda k: [{"x": np.zeros((4, 2),
                                                           np.float32)}])
    assert calls == [3, 1, 2]
    assert len(out) == 3


def test_make_scheduler_resolves_sharded_lazily():
    sched = make_scheduler("sharded")
    assert isinstance(sched, ShardedScheduler)
    # resolution is cached: second lookup hits the class, same behavior
    assert isinstance(make_scheduler("sharded"), ShardedScheduler)
    engine_sched = RoundEngine(
        get_strategy("fedavg"),
        build_context(build_federated(num_clients=4, alpha=1.0, n_train=80,
                                      n_test=40, image_size=16, seed=0),
                      SimConfig()), scheduler="sharded").scheduler
    assert isinstance(engine_sched, ShardedScheduler)


def test_chunk_widths_invariants():
    for G in range(1, 40):
        for D in (1, 2, 4, 8):
            widths = ShardedScheduler._chunk_widths(G, D)
            assert sum(widths) == G
            assert len(widths) <= D
            if G > 1:
                assert all(w >= 2 for w in widths)


def test_chunk_widths_max_lanes():
    # max_lanes bounds widths (the peak-memory knob), may exceed n_dev
    # chunks (round-robin), and never violates the >= 2 floor.
    for G in (2, 5, 17, 40, 100):
        for D in (1, 2, 4):
            for ml in (2, 3, 8, 64):
                widths = ShardedScheduler._chunk_widths(G, D, ml)
                assert sum(widths) == G
                assert all(w >= 2 for w in widths)
                # widths exceed max_lanes only when the >= 2 floor wins
                assert all(w <= max(ml, 3) for w in widths)
    # None keeps the legacy one-chunk-per-device split
    assert (ShardedScheduler._chunk_widths(10, 4, None)
            == ShardedScheduler._chunk_widths(10, 4))
    # sharded results are unchanged by max_lanes (same jitted callable,
    # narrower stacks): rerun the tiny fedavg round with max_lanes=2
    sv, _ = _tiny_run("fedavg", VectorizedScheduler(min_group=1))
    ss, _ = _tiny_run("fedavg", ShardedScheduler(min_group=1, max_lanes=2))
    assert _trees_equal(sv, ss)


# ==========================================================================
# psum masked aggregation vs aggregate_masked (1-device mesh, in-process)
# ==========================================================================
def _random_tree(rng, scale=1.0):
    return {"a": rng.normal(size=(3, 4)).astype(np.float32) * scale,
            "b": {"w": rng.normal(size=(5,)).astype(np.float32) * scale}}


@pytest.mark.parametrize("seed", range(5))
def test_psum_partials_match_aggregate_masked(seed):
    rng = np.random.default_rng(seed)
    G = int(rng.integers(1, 6))
    glob = _random_tree(rng)
    locals_ = [_random_tree(rng) for _ in range(G)]
    # per-leaf {0,1} masks, shared across the group (the fedepth
    # contract: one decomposition -> one mask), incl. the all-zero leaf
    # case (nobody trained -> global passes through)
    mask = jax.tree.map(
        lambda x: np.float32(rng.integers(0, 2)) * np.ones_like(x), glob)
    w = rng.integers(1, 200, size=G).astype(np.float32)

    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_data_mesh
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *locals_)
    mesh = make_data_mesh()               # the in-process 1-device mesh
    partial = jax.jit(shard_map(
        lambda ls, ww, m: psum_masked_partials(ls, m, ww),
        mesh, in_specs=(P("data"), P("data"), P()),
        out_specs=P()))(stacked, jnp.asarray(w), mask)
    got = mesh_aggregate_masked(glob, [partial])

    want = aggregation.aggregate_masked(glob, locals_, list(w),
                                        [mask] * G)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_psum_partials_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def inner(seed):
        test_psum_partials_match_aggregate_masked(seed)

    inner()


# ==========================================================================
# multi-device mesh: the subprocess bitwise assertions (satellite d)
# ==========================================================================
_MESH_SCRIPT = textwrap.dedent("""
    import os
    from repro.launch.mesh import force_host_device_count
    force_host_device_count(4)
    import jax, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.configs.preresnet20 import reduced as rn_reduced
    from repro.fl.data import build_federated
    from repro.fl.engine import RoundEngine, SimConfig, build_context
    from repro.fl.registry import get_strategy
    from repro.fl.sampling import VectorizedScheduler
    from repro.fl.scale.executor import ShardedScheduler

    data = build_federated(num_clients=8, alpha=1.0, n_train=320, n_test=80,
                           image_size=16, seed=0)

    def run(method, scheduler, scenario, codec="none"):
        cfg = rn_reduced(num_classes=10, image_size=16)
        sim = SimConfig(rounds=2, participation=0.75, lr=0.05, local_steps=2,
                        batch_size=32, scenario=scenario, seed=0)
        eng = RoundEngine(get_strategy(method),
                          build_context(data, sim, model_cfg=cfg),
                          scheduler=scheduler, codec=codec)
        return eng.run(eval_every=2)

    # codec off AND on: channel math is host-side on the default path,
    # so the sharded fan-out stays bitwise either way
    for method, scen, codec in [("fedavg", "fair", "none"),
                                ("fedepth", "lack", "none"),
                                ("fedepth", "lack", "topk")]:
        sv, hv = run(method, VectorizedScheduler(min_group=1), scen, codec)
        ss, hs = run(method, ShardedScheduler(min_group=1), scen, codec)
        for a, b in zip(jax.tree.leaves(sv), jax.tree.leaves(ss)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \\
                (method, scen, codec)
        assert [r.comm_bytes for r in hv] == [r.comm_bytes for r in hs]

    # fused on-mesh aggregation: tolerance across devices (psum
    # reassociates partial sums), bitwise is the 1-device contract
    sv, _ = run("fedepth", VectorizedScheduler(min_group=1), "lack")
    ss, _ = run("fedepth", ShardedScheduler(min_group=1, aggregate="mesh"),
                "lack")
    for a, b in zip(jax.tree.leaves(sv), jax.tree.leaves(ss)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    print("MESH-EQUIV-OK")
""")


def test_sharded_bitwise_on_forced_multi_device_mesh(multi_device_env):
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, timeout=560,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=multi_device_env)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MESH-EQUIV-OK" in out.stdout


def test_force_host_device_count_sets_flag_before_init(multi_device_env):
    script = textwrap.dedent("""
        import os
        from repro.launch.mesh import force_host_device_count
        force_host_device_count(3)
        assert "--xla_force_host_platform_device_count=3" \\
            in os.environ["XLA_FLAGS"]
        import jax
        assert len(jax.devices()) == 3
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh()
        assert mesh.shape == {"data": 3}
        # calling again with the SAME n after init is a no-op...
        force_host_device_count(3)
        # ...but a different n after init must fail loudly, not silently
        try:
            force_host_device_count(8)
        except RuntimeError:
            print("FORCE-OK")
        else:
            raise SystemExit("expected RuntimeError after backend init")
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=240,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=multi_device_env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FORCE-OK" in out.stdout


# ==========================================================================
# SpillStore: round-trip, LRU bound, codec (satellite d)
# ==========================================================================
def test_spillstore_round_trip_and_lru_bound(tmp_path):
    with SpillStore(capacity=4, dir=str(tmp_path / "spill")) as store:
        values = {}
        rng = np.random.default_rng(0)
        for k in range(20):
            # the shapes EF actually stores: (tag, residual-pytree)
            values[k] = (("tag", k % 3),
                         {"w": rng.normal(size=(3, 2)).astype(np.float32),
                          "lst": [1, 2.5, None, "s"]})
            store[k] = values[k]
            assert store.resident() <= 4
        assert len(store) == 20
        assert store.spill_count >= 16
        for k in range(20):                       # reload everything
            got = store.get(k)
            assert got[0] == values[k][0]
            np.testing.assert_array_equal(got[1]["w"], values[k][1]["w"])
            assert got[1]["lst"] == values[k][1]["lst"]
            assert store.resident() <= 4
        assert store.load_count > 0
        # pop removes from disk too
        store.pop(0)
        assert 0 not in store and len(store) == 19
        store.clear()
        assert len(store) == 0


def test_spillstore_lru_bound_property(tmp_path):
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["set", "get", "pop"]),
                              st.integers(0, 12)), max_size=60))
    def inner(ops):
        store = SpillStore(capacity=3, dir=str(tmp_path / "prop"))
        shadow = {}
        for op, k in ops:
            if op == "set":
                store[k] = {"v": np.full((2,), k, np.float32)}
                shadow[k] = k
            elif op == "get":
                got = store.get(k)
                if k in shadow:
                    np.testing.assert_array_equal(
                        got["v"], np.full((2,), shadow[k], np.float32))
                else:
                    assert got is None
            else:
                store.pop(k)
                shadow.pop(k, None)
            assert store.resident() <= 3
            assert len(store) == len(shadow)
        store.clear()

    inner()


def test_codec_round_trips_tuple_vs_list_structure():
    # tuple-vs-list is pytree STRUCTURE: trees_congruent must still
    # match after spill/load (the EF same-coordinates check depends on
    # it)
    from repro.fl.comm.codecs import trees_congruent
    tree = {"a": (np.ones((2, 2), np.float32), [np.zeros(3, np.int32)]),
            "b": None, "c": 7}
    got = loads(dumps(tree))
    assert trees_congruent(tree, got)
    assert isinstance(got["a"], tuple) and isinstance(got["a"][1], list)
    # pickle escape hatch: dataclass payloads survive
    res = ClientResult(np.ones(2, np.float32), 2.0, comm_bytes=8)
    got = loads(dumps(res))
    assert isinstance(got, ClientResult) and got.weight == 2.0


def test_prefixed_store_namespaces_do_not_collide():
    base = InMemoryStore()
    a, b = PrefixedStore(base, "ef"), PrefixedStore(base, "downlink")
    a[1] = "ra"
    b[1] = "rb"
    assert a.get(1) == "ra" and b.get(1) == "rb"
    assert len(base) == 2
    a.clear()
    assert a.get(1) is None and b.get(1) == "rb"


# ==========================================================================
# error feedback through a bounded store (satellite c)
# ==========================================================================
def test_error_feedback_residual_survives_spill_cycle(tmp_path):
    with SpillStore(capacity=1, dir=str(tmp_path / "ef")) as store:
        ef = ErrorFeedback(store=store)
        t0 = {"w": np.ones((2,), np.float32)}
        ef.update(0, t0, jax.tree.map(lambda x: 0.5 * x, t0), tag="a")
        ef.update(1, t0, jax.tree.map(lambda x: 0.25 * x, t0), tag="b")
        assert store.resident() == 1          # client 0 spilled to disk
        # reload across the spill boundary: residual AND tag intact
        corrected = ef.correct(0, t0, tag="a")
        np.testing.assert_allclose(corrected["w"], 1.5 * np.ones(2))
        # tag mismatch after a spill cycle still resets, never misapplies
        assert ef.correct(1, t0, tag="CHANGED")["w"] is t0["w"]
        assert ef.residual(1) is None
        ef.reset()
        assert len(store) == 0


def test_channel_state_store_routes_ef_and_downlink(tmp_path):
    with SpillStore(capacity=8, dir=str(tmp_path / "chan")) as store:
        chan = CommChannel("topk", downlink="delta", state_store=store)
        assert isinstance(chan.ef._residuals, PrefixedStore)
        assert chan.ef._residuals.store is store
        assert chan._last_sent.store is store
        # eviction/reset: residuals can be dropped wholesale
        chan.ef.update(3, {"w": np.ones(2, np.float32)},
                       {"w": np.zeros(2, np.float32)}, tag=None)
        assert len(store) == 1
        chan.ef.reset()
        assert len(store) == 0


# ==========================================================================
# lazy population traces (satellite d: determinism per seed)
# ==========================================================================
def test_population_determinism_is_positional_not_sequential():
    a = Population(num_clients=1_000_000, scenario="fair", seed=7)
    b = Population(num_clients=1_000_000, scenario="fair", seed=7)
    ids = np.asarray([0, 999_999, 123_456, 42])
    # query in different orders / batch shapes: same per-client trace
    np.testing.assert_array_equal(a.ratio(ids), b.ratio(ids[::-1])[::-1])
    np.testing.assert_array_equal(a.size(ids),
                                  np.concatenate([b.size(ids[:2]),
                                                  b.size(ids[2:])]))
    np.testing.assert_array_equal(a.labels(123_456), b.labels(123_456))
    np.testing.assert_array_equal(a.phase(ids), b.phase(ids))
    assert a.profile(999_999) is b.profile(999_999)
    # a different seed draws a different trace
    c = Population(num_clients=1_000_000, scenario="fair", seed=8)
    assert not np.array_equal(a.size(np.arange(64)), c.size(np.arange(64)))


def test_population_draws_follow_paper_protocol():
    pop = Population(num_clients=50_000, scenario="lack", seed=0)
    ids = np.arange(2000)
    from repro.fl.engine import SCENARIOS
    assert set(np.unique(pop.ratio(ids))) <= set(SCENARIOS["lack"])
    sizes = pop.size(ids)
    assert sizes.min() >= pop.size_range[0]
    assert sizes.max() <= pop.size_range[1]
    labs = pop.labels(17)
    assert len(set(labs.tolist())) == pop.labels_per_client
    up = pop.up(ids, t=0.0)
    assert 0.6 < up.mean() < 0.9                  # duty=0.75


def test_population_context_is_lazy_and_engine_compatible():
    pop = Population(num_clients=1_000_000, scenario="fair", seed=1)
    sim = SimConfig(rounds=1, participation=0.000004, lr=0.05,
                    local_steps=1, batch_size=16, seed=0)
    cfg = rn_reduced(num_classes=10, image_size=16)
    ctx = build_context(None, sim, population=pop, model_cfg=cfg)
    assert ctx.num_clients == 1_000_000
    assert len(ctx.sizes) == 1_000_000
    # decomps memoized per budget: <= 4 distinct objects for the scenario
    decs = {id(ctx.decomps[k]) for k in
            np.random.default_rng(0).integers(0, 1_000_000, size=50)}
    assert len(decs) <= 4
    # a full (tiny-cohort) round runs end to end on the lazy context
    engine = RoundEngine(get_strategy("fedepth"), ctx, scheduler="sharded",
                         sampler=PopulationSampler(availability=pop))
    state, hist = engine.run(eval_every=1)
    assert len(hist) == 1 and hist[0].accuracy is not None


def test_population_sampler_is_o_cohort_and_availability_aware():
    pop = Population(num_clients=1_000_000, seed=0, avail_duty=0.5)
    sim = SimConfig(participation=0.00001, seed=3)
    cfg = rn_reduced(num_classes=10, image_size=16)
    ctx = build_context(None, sim, population=pop, model_cfg=cfg)
    cohort = PopulationSampler(availability=pop).sample(ctx, round_idx=2)
    assert len(cohort) == 10 == len(set(cohort.tolist()))
    t = 2 * 60.0
    assert pop.up(cohort, t).all()                # all sampled clients up


def test_hashed_duty_cycle_matches_protocol():
    av = HashedDutyCycle(period_s=100.0, duty=0.3, seed=5)
    ids = np.arange(10_000)
    up = av.up(ids, 12.0)
    assert 0.25 < up.mean() < 0.35
    # deterministic + time-varying
    np.testing.assert_array_equal(up, HashedDutyCycle(100.0, 0.3,
                                                      seed=5).up(ids, 12.0))
    assert not np.array_equal(up, av.up(ids, 50.0))


def test_population_system_satisfies_async_engine_contract():
    pop = Population(num_clients=12_345, seed=0)
    system = population_system(pop)
    assert len(system.profiles) == 12_345
    assert system.profiles[77] is pop.profile(77)


# ==========================================================================
# streaming history sinks (satellite b)
# ==========================================================================
def test_round_engine_streams_records_to_sink(tmp_path):
    path = tmp_path / "hist.jsonl"
    data = build_federated(num_clients=4, alpha=1.0, n_train=80, n_test=40,
                           image_size=16, seed=0)
    sim = SimConfig(rounds=3, participation=0.5, local_steps=1,
                    batch_size=16, seed=0)
    cfg = rn_reduced(num_classes=10, image_size=16)
    with JsonlHistorySink(str(path)) as sink:
        engine = RoundEngine(get_strategy("fedavg"),
                             build_context(data, sim, model_cfg=cfg),
                             history_sink=sink)
        state, hist = engine.run(eval_every=1)
        assert hist == []                        # streamed, not retained
        assert sink.records == 3
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["round"] for r in rows] == [1, 2, 3]
    assert all(r["kind"] == "round" for r in rows)
    assert all(r["comm_bytes"] > 0 for r in rows)


def test_async_engine_streams_records_and_trace(tmp_path):
    from repro.fl.systime.engine import AsyncEngine
    path = tmp_path / "async.jsonl"
    pop = Population(num_clients=10_000, scenario="fair", seed=1)
    sim = SimConfig(rounds=2, participation=0.0008, lr=0.05, local_steps=1,
                    batch_size=16, seed=0)
    cfg = rn_reduced(num_classes=10, image_size=16)
    ctx = build_context(None, sim, population=pop, model_cfg=cfg)
    store = InMemoryStore()
    with JsonlHistorySink(str(path)) as sink:
        engine = AsyncEngine(get_strategy("fedepth"), ctx,
                             system=population_system(pop),
                             mode="async", concurrency=4,
                             history_sink=sink, state_store=store)
        state, hist = engine.run(eval_every=1)
        assert hist == [] and engine.trace == []     # both streamed
        assert sink.records >= 1 and sink.traces >= 1
    kinds = {json.loads(line)["kind"]
             for line in path.read_text().splitlines()}
    assert kinds == {"round", "trace"}
    # in-flight snapshots were parked in the store under ("inflight", ...)
    # keys; whatever is left belongs to updates still in flight at exit
    assert all(k[0] == "inflight" for k in store.keys())


def test_sink_default_behavior_unchanged_without_sink():
    data = build_federated(num_clients=4, alpha=1.0, n_train=80, n_test=40,
                           image_size=16, seed=0)
    sim = SimConfig(rounds=2, participation=0.5, local_steps=1,
                    batch_size=16, seed=0)
    cfg = rn_reduced(num_classes=10, image_size=16)
    engine = RoundEngine(get_strategy("fedavg"),
                         build_context(data, sim, model_cfg=cfg))
    _, hist = engine.run(eval_every=1)
    assert [r.round for r in hist] == [1, 2]      # the list API, as ever
