"""Optimizers, schedules, checkpointing, token pipeline, sharding rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data.tokens import TokenPipeline
from repro.launch import sharding, steps as step_lib
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.train import checkpoint, optim


# ---------------------------------------------------------------- schedules
def test_cosine_schedule_shape():
    s = optim.cosine(1.0, 100, warmup=10)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, abs=1e-5)
    assert float(s(jnp.int32(100))) == pytest.approx(0.0, abs=1e-5)
    mid = float(s(jnp.int32(55)))
    assert 0.4 < mid < 0.6


def test_wsd_schedule_phases():
    s = optim.wsd(2.0, 1000)
    assert float(s(jnp.int32(1))) < 2.0                 # warmup
    assert float(s(jnp.int32(500))) == pytest.approx(2.0)  # stable
    assert float(s(jnp.int32(999))) < 0.2               # decay


def test_sgd_momentum_descends_quadratic():
    opt = optim.sgd(optim.constant(0.02), momentum=0.9)
    x = {"w": jnp.array([5.0, -3.0])}
    st = opt.init(x)
    for i in range(120):
        g = jax.tree.map(lambda v: 2 * v, x)  # grad of ||x||^2
        x, st = opt.update(x, g, st, jnp.int32(i))
    assert float(jnp.abs(x["w"]).max()) < 1e-2


def test_adamw_descends():
    opt = optim.adamw(optim.constant(0.05), weight_decay=0.0)
    x = {"w": jnp.array([5.0, -3.0])}
    st = opt.init(x)
    for i in range(200):
        g = jax.tree.map(lambda v: 2 * v, x)
        x, st = opt.update(x, g, st, jnp.int32(i))
    assert float(jnp.abs(x["w"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_nested():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": [jnp.zeros((2,)), (jnp.ones((1,)), jnp.full((3,), 7))],
            "c": {"d": jnp.float32(3.5)}}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        checkpoint.save(p, tree, {"note": "hi"})
        back, meta = checkpoint.load(p)
    assert meta["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention():
    tree = {"w": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        for r in range(6):
            checkpoint.save_round(d, r, tree, keep=3)
        kept = sorted(os.listdir(d))
        assert len(kept) == 3
        assert checkpoint.latest(d).endswith("round_000005.npz")


# ------------------------------------------------------------ token pipeline
def test_token_pipeline_determinism_and_shards():
    tp = TokenPipeline(vocab_size=256, seq_len=16, batch_size=4, seed=3)
    a = next(tp.batches(host_id=0))
    b = next(TokenPipeline(vocab_size=256, seq_len=16, batch_size=4,
                           seed=3).batches(host_id=0))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = next(tp.batches(host_id=1))
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted with -100 tail
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert np.all(a["labels"][:, -1] == -100)


def test_token_pipeline_learnable_structure():
    """Bigram statistics are far from uniform (the LM has signal)."""
    tp = TokenPipeline(vocab_size=128, seq_len=256, batch_size=16, seed=0)
    toks = next(tp.batches())["tokens"]
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs[(int(a), int(b))] = pairs.get((int(a), int(b)), 0) + 1
    # top bigram much more frequent than uniform expectation
    top = max(pairs.values())
    uniform = toks.size / (128 * 128)
    assert top > 20 * uniform


# ---------------------------------------------------------------- sharding
def test_param_specs_cover_tree_and_divide():
    mesh = make_host_mesh()
    for arch in ("yi-6b", "qwen3-moe-235b-a22b", "rwkv6-7b", "zamba2-1.2b",
                 "whisper-small"):
        cfg = get_reduced_config(arch)
        lm = build(cfg)
        shapes = step_lib.abstract_params(lm)
        specs = sharding.param_specs(cfg, shapes, mesh)
        flat_shapes = jax.tree.leaves(shapes)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec))
        assert len(flat_shapes) == len(flat_specs)
        for sd, spec in zip(flat_shapes, flat_specs):
            assert len(spec) <= len(sd.shape)
            for dim, ax in zip(sd.shape, tuple(spec)):
                if ax is not None:
                    assert dim % mesh.shape[ax] == 0


def test_train_step_on_host_mesh():
    """jit with shardings on the 1-device host mesh still runs (the same
    code path the production mesh lowers)."""
    mesh = make_host_mesh()
    cfg = get_reduced_config("yi-6b")
    lm = build(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    pspecs = sharding.to_named(
        sharding.param_specs(cfg, step_lib.abstract_params(lm), mesh), mesh)
    step = step_lib.make_train_step(lm, kernel_force="ref")
    opt = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    with mesh:
        jitted = jax.jit(step, in_shardings=(pspecs, None, None),
                         out_shardings=(pspecs, None, None))
        p2, o2, m = jitted(params, opt, {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(m["loss"]))
