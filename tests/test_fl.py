"""FL runtime: data partitions, width slicing, baselines, end-to-end rounds."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.preresnet20 import CONFIG as RN20, reduced as rn_reduced
from repro.fl import baselines, width as width_util
from repro.fl.data import build_federated, dirichlet_partition
from repro.fl.engine import (RoundEngine, SimConfig, build_context,
                             client_ratios)
from repro.fl.registry import get_strategy
from repro.models import resnet


def _run_experiment(method, data, sim, *, model_cfg, eval_every=5):
    """The engine-API equivalent of the removed run_experiment shim."""
    engine = RoundEngine(get_strategy(method),
                         build_context(data, sim, model_cfg=model_cfg))
    _, hist = engine.run(eval_every=eval_every)
    return hist[-1].accuracy, hist


@pytest.fixture(scope="module")
def tiny_data():
    return build_federated(num_clients=8, partition="dirichlet", alpha=1.0,
                           n_train=640, n_test=200, image_size=16, seed=0)


@pytest.fixture(scope="module")
def tiny_cfg():
    return rn_reduced(num_classes=10, image_size=16)


# ---------------------------------------------------------------- width ops
def test_slice_then_pad_roundtrip():
    key = jax.random.PRNGKey(0)
    params = resnet.init(key, RN20)
    sub, sub_cfg = width_util.slice_resnet(params, RN20, 0.5)
    padded, mask = width_util.pad_resnet(sub, RN20, sub_cfg)
    # padded values inside the mask equal the original slice
    flat_p = width_util._flatten(padded)
    flat_m = width_util._flatten(mask)
    flat_g = width_util._flatten(params)
    for k in flat_p:
        inside = np.asarray(flat_m[k]) > 0
        np.testing.assert_allclose(np.asarray(flat_p[k])[inside],
                                   np.asarray(flat_g[k])[inside], rtol=1e-6)
        # outside the mask is zero
        assert np.all(np.asarray(flat_p[k])[~inside] == 0)


def test_sliced_subnet_runs():
    key = jax.random.PRNGKey(1)
    params = resnet.init(key, RN20)
    for r in (1 / 8, 1 / 4, 1 / 2):
        sub, sub_cfg = width_util.slice_resnet(params, RN20, r)
        out = resnet.apply(sub, sub_cfg, jnp.zeros((2, 32, 32, 3)))
        assert out.shape == (2, 10)


def test_heterofl_aggregate_respects_coverage():
    g = {"w": jnp.zeros((4,))}
    p1 = {"w": jnp.array([1.0, 1.0, 0.0, 0.0])}
    m1 = {"w": jnp.array([1.0, 1.0, 0.0, 0.0])}
    p2 = {"w": jnp.array([3.0, 3.0, 3.0, 0.0])}
    m2 = {"w": jnp.array([1.0, 1.0, 1.0, 0.0])}
    out = baselines.heterofl_aggregate(g, [p1, p2], [m1, m2], [1.0, 1.0])
    np.testing.assert_allclose(out["w"], [2.0, 2.0, 3.0, 0.0])


# ---------------------------------------------------------------- scenarios
def test_client_ratio_distribution():
    r = client_ratios(100, "fair")
    vals, counts = np.unique(np.round(r, 4), return_counts=True)
    assert len(vals) == 4
    assert counts.max() - counts.min() <= 1


def test_depthfl_budget_to_depth_monotone():
    cfg = RN20
    from repro.core.memory_model import resnet_memory
    mem = resnet_memory(cfg, 128)
    budgets = [mem.full_train_bytes() * f for f in (0.2, 0.5, 1.0)]
    depths = [baselines.depthfl_depth_for_budget(cfg, int(b), 128)
              for b in budgets]
    assert depths == sorted(depths)
    assert depths[-1] == cfg.num_blocks


# ---------------------------------------------------------------- end-to-end
@pytest.mark.parametrize("method", ["fedavg", "heterofl", "fedepth"])
def test_run_experiment_smoke(method, tiny_data, tiny_cfg):
    sim = SimConfig(rounds=2, participation=0.5, lr=0.05, local_steps=1,
                    batch_size=32, scenario="fair", seed=0)
    acc, hist = _run_experiment(method, tiny_data, sim, model_cfg=tiny_cfg,
                                eval_every=2)
    assert 0.0 <= acc <= 1.0
    assert len(hist) >= 1


def test_fedepth_learns_above_chance(tiny_data, tiny_cfg):
    # Investigated flake: the skip-head path does NOT under-train — the
    # global model learns, but single-round accuracy oscillates hard on
    # this tiny config (4/8 non-IID clients per cohort at the paper's
    # lr=0.08; rounds 7..12 read 0.225, 0.14, 0.205, 0.265, 0.14, 0.285
    # on seed 0), so the old single-snapshot assert (round 8 = 0.14 vs a
    # 0.15 threshold) was a coin flip on cohort composition.  Assert the
    # actual claim — learning above chance — on the mean of the last
    # three evals (rounds 8/10/12 -> 0.23), well clear of chance 0.10.
    sim = SimConfig(rounds=12, participation=0.5, lr=0.08, local_steps=2,
                    batch_size=64, scenario="fair", seed=0)
    _, hist = _run_experiment("fedepth", tiny_data, sim,
                              model_cfg=tiny_cfg, eval_every=2)
    tail = [rec.accuracy for rec in hist[-3:]]
    assert sum(tail) / len(tail) > 0.15  # 10 classes -> chance is 0.10


def test_fedepth_robust_to_scenarios(tiny_data, tiny_cfg):
    """FeDepth runs under all three budget scenarios without error
    (paper: robustness to heterogeneous budgets)."""
    for scen in ("fair", "lack", "surplus"):
        sim = SimConfig(rounds=1, participation=0.5, lr=0.05, local_steps=1,
                        batch_size=32, scenario=scen, seed=0)
        acc, _ = _run_experiment("m-fedepth", tiny_data, sim,
                                 model_cfg=tiny_cfg, eval_every=1)
        assert 0.0 <= acc <= 1.0
