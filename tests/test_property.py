"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation
from repro.core.decomposition import decompose
from repro.core.memory_model import ModelMemory, UnitCost
from repro.fl.data import dirichlet_partition, pathological_partition
from repro.roofline.analysis import collective_bytes

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------- decompose
@st.composite
def memories(draw):
    n = draw(st.integers(2, 12))
    units = []
    for i in range(n):
        p = draw(st.integers(1_000, 500_000))
        a = draw(st.integers(1_000, 5_000_000))
        units.append(UnitCost(f"u{i}", p, a, a // 4))
    embed = UnitCost("embed", 10_000, 50_000, 50_000)
    head = UnitCost("head", 20_000, 80_000, 1_000)
    return ModelMemory(units, embed, head)


@given(memories(), st.floats(0.05, 1.5))
def test_decomposition_invariants(mem, frac):
    budget = int(mem.full_train_bytes() * frac)
    try:
        dec = decompose(mem, budget)
    except MemoryError:
        return
    n = len(mem.units)
    # blocks are contiguous, ordered, non-overlapping, end at n
    prev = dec.skipped_prefix
    for lo, hi in dec.blocks:
        assert lo == prev and hi > lo
        prev = hi
    assert prev == n
    # every block respects the budget
    for lo, hi in dec.blocks:
        assert mem.block_train_bytes(lo, hi) <= budget
    # maximality: no block could absorb its successor
    for i in range(len(dec.blocks) - 1):
        lo, hi = dec.blocks[i]
        nxt_hi = dec.blocks[i + 1][1]
        assert mem.block_train_bytes(lo, min(hi + 1, nxt_hi)) > budget or \
            hi + 1 > n


@given(memories())
def test_bigger_budget_no_more_blocks(mem):
    b1 = int(mem.full_train_bytes() * 0.3)
    b2 = int(mem.full_train_bytes() * 0.9)
    try:
        d1 = decompose(mem, b1)
        d2 = decompose(mem, b2)
    except MemoryError:
        return
    assert d2.num_blocks + d2.skipped_prefix <= d1.num_blocks + d1.skipped_prefix + len(mem.units)
    assert d2.skipped_prefix <= d1.skipped_prefix


# ---------------------------------------------------------------- fedavg
@given(st.integers(2, 5), st.integers(1, 4),
       st.lists(st.floats(0.1, 10.0), min_size=2, max_size=5))
def test_fedavg_convexity(n_clients, dim, weights):
    if len(weights) != n_clients:
        weights = (weights * n_clients)[:n_clients]
    rng = np.random.default_rng(0)
    trees = [{"w": jnp.asarray(rng.normal(size=(dim,)))}
             for _ in range(n_clients)]
    avg = aggregation.fedavg(trees, weights)
    lo = np.min([t["w"] for t in trees], axis=0)
    hi = np.max([t["w"] for t in trees], axis=0)
    assert np.all(np.asarray(avg["w"]) >= lo - 1e-5)
    assert np.all(np.asarray(avg["w"]) <= hi + 1e-5)


@given(st.integers(2, 6))
def test_fedavg_permutation_invariant(n):
    rng = np.random.default_rng(1)
    trees = [{"w": jnp.asarray(rng.normal(size=(3,)))} for _ in range(n)]
    ws = list(rng.uniform(0.5, 2.0, size=n))
    a = aggregation.fedavg(trees, ws)
    perm = rng.permutation(n)
    b = aggregation.fedavg([trees[i] for i in perm], [ws[i] for i in perm])
    np.testing.assert_allclose(a["w"], b["w"], atol=1e-5)


# ---------------------------------------------------------------- partitions
@given(st.integers(3, 20), st.floats(0.1, 10.0))
def test_dirichlet_partition_covers(num_clients, alpha):
    rng = np.random.default_rng(2)
    y = rng.integers(0, 10, size=500).astype(np.int32)
    parts = dirichlet_partition(y, num_clients, alpha, balanced=False, seed=3)
    assert len(parts) == num_clients
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert all_idx.max() < len(y) and all_idx.min() >= 0
    # unbalanced partition never duplicates an index across clients
    assert len(np.unique(all_idx)) == len(all_idx)


@given(st.integers(4, 20))
def test_balanced_partition_equal_sizes(num_clients):
    rng = np.random.default_rng(4)
    y = rng.integers(0, 10, size=1000).astype(np.int32)
    parts = dirichlet_partition(y, num_clients, 0.5, balanced=True, seed=5)
    sizes = {len(p) for p in parts}
    assert len(sizes) == 1


@given(st.integers(4, 16), st.integers(2, 5))
def test_pathological_partition_label_budget(num_clients, labels_per):
    rng = np.random.default_rng(6)
    y = rng.integers(0, 10, size=800).astype(np.int32)
    parts = pathological_partition(y, num_clients, labels_per, seed=7)
    for p in parts:
        assert len(np.unique(y[p])) <= labels_per


# ---------------------------------------------------------------- HLO parse
@given(st.integers(1, 4), st.integers(1, 64), st.integers(1, 64))
def test_collective_bytes_parser(n, a, b):
    hlo = "\n".join(
        f"  %ar.{i} = f32[{a},{b}] all-reduce(f32[{a},{b}] %x.{i})"
        for i in range(n))
    out = collective_bytes(hlo)
    assert out.get("all-reduce", 0) == n * a * b * 4


def test_collective_bytes_mixed_kinds():
    hlo = """
  %ag = bf16[8,128] all-gather(bf16[1,128] %p), dimensions={0}
  %ar = f32[64] all-reduce(f32[64] %q), to_apply=%add
  %a2a = f32[4,32] all-to-all(f32[4,32] %r)
  %cp = u32[16] collective-permute(u32[16] %s)
  %done = f32[64] all-reduce-done(f32[64] %ar2)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["all-to-all"] == 4 * 32 * 4
    assert out["collective-permute"] == 16 * 4
