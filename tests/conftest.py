"""Shared fixtures.  NOTE: tests run on the default single CPU device —
never import repro.launch.dryrun here (it forces 512 host devices)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
