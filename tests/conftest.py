"""Shared fixtures.  NOTE: tests run on the default single CPU device —
never import repro.launch.dryrun here (it forces 512 host devices)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def multi_device_env():
    """Clean environment for SUBPROCESS tests that need a forced
    multi-device CPU mesh.  XLA reads ``XLA_FLAGS`` exactly once, at
    backend init — this parent process already initialized jax on one
    device, so multi-device tests must run in a fresh interpreter whose
    script sets ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (or calls ``repro.launch.mesh.force_host_device_count``) BEFORE any
    jax import touches the backend.  See docs/scale.md §Testing on a
    forced mesh."""
    return {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
            "HOME": "/root", "JAX_PLATFORMS": "cpu"}
