"""Docs stay navigable: no dead relative links in README.md / docs/."""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_no_dead_relative_links():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    files = [REPO / "README.md"] + sorted((REPO / "docs").rglob("*.md"))
    errors = [e for f in files for e in check_links.check_file(f)]
    assert not errors, "\n".join(errors)
