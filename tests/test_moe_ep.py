"""shard_map expert-parallel MoE (explicit all_to_all) vs the portable
scatter-dispatch path: exact agreement on a multi-device host mesh.

NOTE: this file spawns a subprocess so the 8-device XLA_FLAGS never leak
into the main test process (everything else runs on 1 device).
"""
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_reduced_config
from repro.models import moe, moe_ep, common

cfg = get_reduced_config("qwen3-moe-235b-a22b")  # 4 experts, top-2
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
p = moe.init(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, cfg.d_model)) * 0.5

ref_out, _ = moe.forward(p, cfg, x, capacity_factor=8.0)
with mesh:
    ep_out, _ = moe_ep.forward_ep(p, cfg, x, mesh, capacity_factor=8.0)
err = float(jnp.abs(ep_out - ref_out).max())
assert err < 1e-4, err

# and the context-based delegation inside moe.forward
with mesh, common.ep_moe():
    del_out, _ = moe.forward(p, cfg, x, capacity_factor=8.0)
err2 = float(jnp.abs(del_out - ref_out).max())
assert err2 < 1e-4, err2
print("EP_OK", err, err2)
"""


def test_moe_ep_matches_dense_dispatch():
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=560,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # the 8-device mesh is a CPU host-platform
                              # trick; never let a libtpu install hijack
                              # the stripped subprocess env
                              "JAX_PLATFORMS": "cpu"}, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "EP_OK" in out.stdout
