"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family runs one forward/train step and one decode step on
CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.configs.shapes import shape_applicable, SHAPE_BY_NAME
from repro.launch import steps as step_lib
from repro.models import build, init_cache


def _batch(cfg, key, B=2, T=16):
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        b["encoder_embeds"] = jax.random.normal(
            key, (B, cfg.max_source_positions, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        P = cfg.frontend_embed_tokens
        b["vision_embeds"] = jax.random.normal(key, (B, P, cfg.d_model)) * 0.1
        b["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32), (3, B, T))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_constraints(arch):
    cfg = get_reduced_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_reduced_config(arch)
    lm = build(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    batch = _batch(cfg, jax.random.fold_in(key, 1))

    loss, metrics = lm.loss_fn(params, batch, kernel_force="ref")
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))

    step = step_lib.make_train_step(lm, lr=1e-2, kernel_force="ref")
    opt = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    params2, opt2, m = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved
    # another step reduces loss on the same batch (sanity, not always
    # monotone — allow small tolerance)
    loss2, _ = lm.loss_fn(params2, batch, kernel_force="ref")
    assert float(loss2) < float(loss) + 0.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_reduced_config(arch)
    lm = build(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    B, S = 2, 12
    cache = init_cache(cfg, B, S)
    if cfg.is_encoder_decoder:
        cache["enc_out"] = (jax.random.normal(
            key, cache["enc_out"].shape) * 0.1).astype(cache["enc_out"].dtype)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["mrope_positions"] = jnp.zeros((3, B, 1), jnp.int32)
    logits, new_cache = lm.decode_step(params, tok, cache, jnp.int32(0),
                                       kernel_force="ref", **kwargs)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert set(new_cache) == set(cache)
    for k in cache:
        assert new_cache[k].shape == cache[k].shape


@pytest.mark.parametrize("arch", ["yi-6b", "h2o-danube-3-4b", "zamba2-1.2b"])
def test_decode_matches_prefill(arch):
    """Sequential decode logits == prefill last-token logits."""
    cfg = get_reduced_config(arch)
    lm = build(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    B, T = 1, 10
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, T)
    logits = None
    for t in range(T):
        logits, cache = lm.decode_step(params, toks[:, t:t + 1], cache,
                                       jnp.int32(t), kernel_force="ref")
    pf = lm.prefill(params, {"tokens": toks}, kernel_force="ref")
    # bf16 cache states (conv/kv) bound the achievable agreement
    np.testing.assert_allclose(np.asarray(logits), np.asarray(pf),
                               atol=3e-2, rtol=5e-2)


def test_shape_applicability_policy():
    long = SHAPE_BY_NAME["long_500k"]
    dec32 = SHAPE_BY_NAME["decode_32k"]
    # sub-quadratic archs run long_500k
    for arch in ("rwkv6-7b", "zamba2-1.2b", "h2o-danube-3-4b"):
        ok, _ = shape_applicable(get_config(arch), long)
        assert ok, arch
    # pure full-attention archs skip it
    for arch in ("yi-6b", "qwen2-7b", "qwen3-moe-235b-a22b"):
        ok, why = shape_applicable(get_config(arch), long)
        assert not ok and "quadratic" in why
    # whisper decode_32k runs (extended positions); long_500k does not
    ok, _ = shape_applicable(get_config("whisper-small"), dec32)
    assert ok
    ok, why = shape_applicable(get_config("whisper-small"), long)
    assert not ok


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_init(arch):
    """Analytic param_count() agrees with actual init on reduced configs."""
    cfg = get_reduced_config(arch)
    lm = build(cfg)
    shapes = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    analytic = cfg.param_count()
    # analytic model ignores small extras (norm scales, lora adapters,
    # positional embeddings): require agreement within 20%
    assert abs(actual - analytic) / actual < 0.20, (actual, analytic)
