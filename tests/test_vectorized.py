"""VectorizedScheduler: grouping, fallback, and scheduler-equivalence.

The contract under test (see docs/architecture.md "Vectorized cohort
execution"): scheduler choice changes wall-clock, never the experiment —
same batches drawn from the shared stream, numerically matching
aggregated params (up to float associativity of the stacked ops), and
identical comm-bytes accounting.
"""
import jax
import numpy as np
import pytest

from repro.configs.preresnet20 import reduced as rn_reduced
from repro.core.blockwise import (broadcast_tree, stack_batches, stackable,
                                  unstack_tree)
from repro.fl.data import build_federated
from repro.fl.engine import RoundEngine, RoundRecord, SimConfig, build_context
from repro.fl.registry import get_strategy
from repro.fl.sampling import (SequentialScheduler, VectorizedScheduler,
                               make_scheduler)
from repro.fl.strategy import ClientResult, Context


# ------------------------------------------------------------------ helpers
def _tiny_data(num_clients=6, seed=0):
    return build_federated(num_clients=num_clients, alpha=1.0, n_train=240,
                           n_test=80, image_size=16, seed=seed)


def _run(method, data, scheduler, *, scenario="fair", rounds=2, seed=0):
    cfg = rn_reduced(num_classes=10, image_size=16)
    sim = SimConfig(rounds=rounds, participation=0.5, lr=0.05,
                    local_steps=2, batch_size=32, scenario=scenario,
                    seed=seed)
    engine = RoundEngine(get_strategy(method),
                         build_context(data, sim, model_cfg=cfg),
                         scheduler=scheduler)
    return engine.run(eval_every=rounds)


def _assert_equivalent(method, scenario):
    data = _tiny_data()
    state_seq, hist_seq = _run(method, data, "sequential",
                               scenario=scenario)
    # min_group=1 routes every client through the batched path, so the
    # equivalence claim is exercised even for singleton groups
    state_vec, hist_vec = _run(method, data, VectorizedScheduler(min_group=1),
                               scenario=scenario)
    for a, b in zip(jax.tree.leaves(state_seq), jax.tree.leaves(state_vec)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    assert [r.comm_bytes for r in hist_seq] == \
        [r.comm_bytes for r in hist_vec]
    assert [r.round for r in hist_seq] == [r.round for r in hist_vec]


# -------------------------------------------------------------- equivalence
def test_fedavg_scheduler_equivalence():
    _assert_equivalent("fedavg", "fair")


def test_fedepth_scheduler_equivalence_partial_training():
    # "lack" puts the poorest clients below the finest block: the batched
    # path must reproduce the prefix-skipping decompositions exactly
    _assert_equivalent("fedepth", "lack")


def test_heterofl_scheduler_equivalence():
    # exercises the slice-once + vmap + pad batched path and the cached
    # per-ratio wire bytes (comm accounting must match exactly)
    _assert_equivalent("heterofl", "fair")


# -------------------------------------------------- grouping and fallbacks
class _Recorder:
    """Batchable stub: group key = client id parity, payload = marker."""

    def __init__(self, key_fn=None):
        self.sequential_calls = []
        self.batched_calls = []
        self.key_fn = key_fn or (lambda cid: cid % 2)

    def client_group_key(self, ctx, client_id):
        return self.key_fn(client_id)

    def client_update(self, ctx, state, client_id, batches):
        self.sequential_calls.append(client_id)
        return ClientResult(np.zeros(1), 1.0, comm_bytes=0)

    def client_update_batched(self, ctx, state, client_ids, batches):
        self.batched_calls.append(tuple(client_ids))
        return [ClientResult(np.zeros(1), 1.0, comm_bytes=0)
                for _ in client_ids]


def _stub_ctx(num_clients=8):
    return Context(sim=SimConfig(participation=0.5), num_clients=num_clients,
                   sizes=np.ones(num_clients),
                   rng=np.random.default_rng(0), key=None)


def _batch_fn(k):
    return [{"x": np.zeros((4, 2), np.float32)}]


def test_vectorized_groups_by_key():
    strat = _Recorder()
    out = VectorizedScheduler().run(_stub_ctx(), strat, None,
                                    [0, 1, 2, 3, 4], _batch_fn)
    assert len(out) == 5
    assert sorted(strat.batched_calls) == [(0, 2, 4), (1, 3)]
    assert strat.sequential_calls == []


def test_vectorized_min_group_falls_back():
    strat = _Recorder()
    VectorizedScheduler(min_group=3).run(_stub_ctx(), strat, None,
                                         [0, 1, 2, 3, 4], _batch_fn)
    assert strat.batched_calls == [(0, 2, 4)]    # evens reach min_group
    assert strat.sequential_calls == [1, 3]


def test_vectorized_none_key_falls_back():
    strat = _Recorder(key_fn=lambda cid: None if cid == 2 else "g")
    VectorizedScheduler().run(_stub_ctx(), strat, None, [0, 1, 2, 3],
                              _batch_fn)
    assert strat.batched_calls == [(0, 1, 3)]
    assert strat.sequential_calls == [2]


def test_vectorized_ragged_batches_fall_back():
    strat = _Recorder(key_fn=lambda cid: "g")

    def ragged(k):   # client 1's batch shape differs -> not stackable
        n = 8 if k == 1 else 4
        return [{"x": np.zeros((n, 2), np.float32)}]

    VectorizedScheduler().run(_stub_ctx(), strat, None, [0, 1, 2], ragged)
    assert strat.batched_calls == []
    assert sorted(strat.sequential_calls) == [0, 1, 2]


def test_vectorized_delegates_plain_strategies_wholesale():
    calls = []

    class Plain:
        def client_update(self, ctx, state, client_id, batches):
            calls.append(client_id)
            return ClientResult(np.zeros(1), 1.0, comm_bytes=0)

    out = VectorizedScheduler().run(_stub_ctx(), Plain(), None, [3, 1, 2],
                                    _batch_fn)
    assert calls == [3, 1, 2]          # sequential order preserved
    assert len(out) == 3


def test_results_in_cohort_order():
    class Tagger(_Recorder):
        def client_update_batched(self, ctx, state, client_ids, batches):
            return [ClientResult(np.full(1, cid), 1.0, comm_bytes=0)
                    for cid in client_ids]

    out = VectorizedScheduler().run(_stub_ctx(), Tagger(), None,
                                    [4, 1, 2, 3], _batch_fn)
    assert [int(r.payload[0]) for r in out] == [4, 1, 2, 3]


# ------------------------------------------------------------- plumbing
def test_make_scheduler_resolution():
    assert isinstance(make_scheduler(None), SequentialScheduler)
    assert isinstance(make_scheduler("sequential"), SequentialScheduler)
    assert isinstance(make_scheduler("vectorized"), VectorizedScheduler)
    inst = VectorizedScheduler(min_group=3)
    assert make_scheduler(inst) is inst
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("async")


def test_engine_accepts_scheduler_name():
    engine = RoundEngine(get_strategy("fedavg"), _stub_ctx(),
                         scheduler="vectorized")
    assert isinstance(engine.scheduler, VectorizedScheduler)


# ------------------------------------------------------- stacking helpers
def test_stack_helpers_round_trip():
    batches = [[{"x": np.arange(6, dtype=np.float32).reshape(2, 3) + k}]
               for k in range(3)]
    assert stackable(batches)
    stacked = stack_batches(batches)
    assert stacked["x"].shape == (3, 1, 2, 3)   # (clients, batches, ...)
    tree = {"w": np.ones((2, 2), np.float32)}
    parts = unstack_tree(broadcast_tree(tree, 4), 4)
    assert len(parts) == 4
    np.testing.assert_array_equal(np.asarray(parts[2]["w"]), tree["w"])


def test_stackable_rejects_mismatched_shapes_and_counts():
    a = [{"x": np.zeros((2, 3), np.float32)}]
    b = [{"x": np.zeros((2, 4), np.float32)}]
    assert not stackable([a, b])
    assert not stackable([a, a + a])


# --------------------------------------- engine history contract (bugfix)
def test_history_records_kept_without_eval_source():
    """No eval_fn and ctx.data None used to silently drop records (and
    their seconds/comm_bytes); now they appear with accuracy=None."""

    class Null:
        def init_state(self, ctx):
            return np.zeros(2, np.float32)

        def client_update(self, ctx, state, client_id, batches):
            return ClientResult(np.ones(2, np.float32), 1.0)

        def aggregate(self, ctx, state, results):
            return results[0].payload

        def eval_model(self, ctx, state, x, y):  # pragma: no cover
            raise AssertionError("must not be called without data")

    ctx = _stub_ctx()
    ctx.sim.rounds = 4
    engine = RoundEngine(Null(), ctx)
    _, hist = engine.run(batch_fn=lambda k: [None], eval_every=2)
    assert [r.round for r in hist] == [2, 4]
    assert all(isinstance(r, RoundRecord) for r in hist)
    assert all(r.accuracy is None for r in hist)
    # cohort of ceil(0.5 * 8) = 4 clients x 8-byte payload x 2 rounds
    assert all(r.comm_bytes == 2 * 4 * 8 for r in hist)
    assert all(r.seconds >= 0 for r in hist)
