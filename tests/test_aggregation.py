"""Direct unit tests for core/aggregation: masked aggregation semantics
(skipped-prefix leaves keep global values; weights renormalize over who
trained) and the trained-mask builder on a real runner/decomposition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.preresnet20 import reduced as rn_reduced
from repro.core import aggregation, blockwise
from repro.core.decomposition import Decomposition
from repro.models import resnet


# ---------------------------------------------------------- aggregate_masked
def test_masked_untrained_leaf_keeps_global():
    """A leaf NO client trained must keep the broadcast global value."""
    g = {"skip": jnp.full((3,), 7.0), "train": jnp.zeros((3,))}
    c1 = {"skip": jnp.zeros((3,)), "train": jnp.ones((3,))}
    c2 = {"skip": jnp.zeros((3,)), "train": jnp.full((3,), 3.0)}
    m0 = {"skip": jnp.zeros((3,)), "train": jnp.ones((3,))}
    out = aggregation.aggregate_masked(g, [c1, c2], [1.0, 1.0], [m0, m0])
    np.testing.assert_allclose(out["skip"], 7.0)       # nobody trained
    np.testing.assert_allclose(out["train"], 2.0)      # plain average


def test_masked_weights_renormalize_over_trainers():
    """Weights renormalize over the clients that trained each leaf: a
    heavy client that SKIPPED the leaf contributes nothing to it."""
    g = {"w": jnp.zeros((2,))}
    trained = {"w": jnp.ones((2,))}
    skipped = {"w": jnp.full((2,), 100.0)}   # stale values must not leak
    m_yes, m_no = {"w": jnp.ones((2,))}, {"w": jnp.zeros((2,))}
    # skipped client has 9x the weight — irrelevant: renormalized out
    out = aggregation.aggregate_masked(g, [trained, skipped], [1.0, 9.0],
                                       [m_yes, m_no])
    np.testing.assert_allclose(out["w"], 1.0)


def test_masked_partial_overlap_mixes_correctly():
    g = {"w": jnp.zeros((2,))}
    c1 = {"w": jnp.array([1.0, 1.0])}
    c2 = {"w": jnp.array([3.0, 3.0])}
    m1 = {"w": jnp.array([1.0, 1.0])}
    m2 = {"w": jnp.array([1.0, 0.0])}   # c2 trained only coord 0
    out = aggregation.aggregate_masked(g, [c1, c2], [1.0, 1.0], [m1, m2])
    np.testing.assert_allclose(out["w"], [2.0, 1.0])


def test_masked_matches_fedavg_when_everyone_trains():
    rng = np.random.default_rng(0)
    g = {"w": jnp.zeros((4,))}
    cs = [{"w": jnp.asarray(rng.normal(size=4), jnp.float32)}
          for _ in range(3)]
    ms = [{"w": jnp.ones((4,))} for _ in range(3)]
    w = [1.0, 2.0, 3.0]
    np.testing.assert_allclose(
        aggregation.aggregate_masked(g, cs, w, ms)["w"],
        aggregation.fedavg(cs, w)["w"], rtol=1e-5)


# ---------------------------------------------------------- trained_mask_for
@pytest.fixture(scope="module")
def tiny_runner():
    cfg = rn_reduced(num_classes=4, image_size=16)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    return cfg, params, blockwise.resnet_runner(cfg)


def test_trained_mask_skipped_prefix_is_zero(tiny_runner):
    cfg, params, runner = tiny_runner
    n = cfg.num_blocks
    dec = Decomposition(tuple((i, i + 1) for i in range(1, n)), 1, 0)
    mask = aggregation.trained_mask_for(params, dec, runner)
    # skipped block 0 (and the stem, which trains with block 0) stays 0
    assert all(float(x.max()) == 0.0
               for x in jax.tree.leaves(mask["blocks"][0]))
    assert float(jnp.asarray(mask["stem"]).max()) == 0.0
    # trained blocks and the always-trained head are 1
    for b in range(1, n):
        assert all(float(x.min()) == 1.0
                   for x in jax.tree.leaves(mask["blocks"][b]))
    assert float(jnp.asarray(mask["classifier"]["w"]).min()) == 1.0


def test_trained_mask_full_coverage_is_all_ones(tiny_runner):
    cfg, params, runner = tiny_runner
    dec = Decomposition(((0, cfg.num_blocks),), 0, 0)
    mask = aggregation.trained_mask_for(params, dec, runner)
    assert all(float(x.min()) == 1.0 for x in jax.tree.leaves(mask))
