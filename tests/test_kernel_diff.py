"""Differential kernel-equivalence harness (ISSUE-7 centerpiece).

Goes through the PUBLIC dispatch layer ``kernels.ops`` — the exact code
path the sequence-model runners hit — and asserts that the Pallas kernel
body (``force="interpret"`` on CPU; the same body the TPU path compiles)
agrees with the pure-jnp oracle (``force="ref"``) on

  * the FORWARD values, and
  * the GRADIENTS through the deployed ``jax.custom_vjp`` backward
    (chunked-recompute; this is what training actually differentiates),

for every kernel the mamba2/rwkv6/zamba2/moe fast path uses: flash
attention, the RWKV6 WKV scan, the Mamba2 SSD scan, and chunked
cross-entropy.  Sweeps include non-divisible ``T`` versus the block size
so the ragged-tail masking is covered.

The deterministic sweeps below always run.  A second, hypothesis-driven
layer samples shapes/seeds from a wider space; it is import-gated because
``hypothesis`` is a dev-only extra (requirements-dev.txt — installed in
CI, possibly absent locally).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.kernel_diff

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _allclose(a, b, msg, atol, rtol=0.0):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=atol, rtol=rtol, err_msg=msg)


def _grad_parity(f, args, atol, rtol=0.0):
    """Compare d f(mode, *args) / d args between interpret and ref."""
    nums = tuple(range(len(args)))
    g_int = jax.grad(lambda *a: f("interpret", *a), argnums=nums)(*args)
    g_ref = jax.grad(lambda *a: f("ref", *a), argnums=nums)(*args)
    for i, (gi, gr) in enumerate(zip(g_int, g_ref)):
        _allclose(gi, gr, f"grad of arg {i} mismatch", atol, rtol)


# ------------------------------------------------------------------ attention
@pytest.mark.parametrize("B,T,Hq,Hkv,D", [
    (1, 64, 2, 1, 16),
    (2, 80, 4, 2, 32),   # T=80 ragged vs block 32
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24)])
def test_attention_interpret_vs_ref(B, T, Hq, Hkv, D, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))

    def f(mode, q_, k_, v_):
        return ops.attention(q_, k_, v_, causal=causal,
                             sliding_window=window, block_q=32, block_k=32,
                             force=mode).sum()

    _allclose(ops.attention(q, k, v, causal=causal, sliding_window=window,
                            block_q=32, block_k=32, force="interpret"),
              ops.attention(q, k, v, causal=causal, sliding_window=window,
                            force="ref"),
              "attention forward", atol=2e-5, rtol=2e-5)
    _grad_parity(f, (q, k, v), atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------------ rwkv6
@pytest.mark.parametrize("B,T,H,D,bt", [
    (1, 64, 2, 16, 16),
    (2, 50, 1, 16, 16),   # T=50 ragged vs block 16; bwd chunk 64 > T
])
def test_rwkv6_interpret_vs_ref(B, T, H, D, bt):
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    r = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    w = jax.random.normal(ks[3], (B, T, H, D)) * 0.3
    u = jax.random.normal(ks[4], (H, D)) * 0.1

    def f(mode, r_, k_, v_, w_, u_):
        y, sT = ops.rwkv6(r_, k_, v_, w_, u_, block_t=bt, force=mode)
        # touch BOTH outputs so the state cotangent path is exercised
        return y.sum() + 0.5 * sT.sum()

    y_i, s_i = ops.rwkv6(r, k, v, w, u, block_t=bt, force="interpret")
    y_r, s_r = ops.rwkv6(r, k, v, w, u, force="ref")
    _allclose(y_i, y_r, "rwkv6 forward y", atol=1e-4, rtol=1e-4)
    _allclose(s_i, s_r, "rwkv6 final state", atol=1e-4, rtol=1e-4)
    _grad_parity(f, (r, k, v, w, u), atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------------ mamba2
@pytest.mark.parametrize("B,T,H,P,N,bt", [
    (1, 64, 2, 16, 8, 16),
    (2, 40, 1, 16, 16, 16),   # T=40 ragged vs block 16
])
def test_mamba2_interpret_vs_ref(B, T, H, P, N, bt):
    ks = jax.random.split(jax.random.PRNGKey(12), 6)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))
    D = jax.random.normal(ks[5], (H,))

    def f(mode, x_, dt_, A_, Bm_, Cm_, D_):
        y, hT = ops.mamba2(x_, dt_, A_, Bm_, Cm_, D_, block_t=bt, force=mode)
        return y.sum() + 0.5 * hT.sum()

    y_i, h_i = ops.mamba2(x, dt, A, Bm, Cm, D, block_t=bt, force="interpret")
    y_r, h_r = ops.mamba2(x, dt, A, Bm, Cm, D, force="ref")
    scale = max(float(jnp.abs(y_r).max()), 1.0)
    _allclose(y_i / scale, y_r / scale, "mamba2 forward y", atol=2e-5,
              rtol=2e-5)
    _allclose(h_i, h_r, "mamba2 final state", atol=1e-4, rtol=1e-3)
    _grad_parity(f, (x, dt, A, Bm, Cm, D), atol=5e-4, rtol=5e-4)


# ------------------------------------------------------------------ chunked CE
@pytest.mark.parametrize("B,T,D,V,bt,bv", [
    (2, 16, 16, 64, 8, 32),
    (1, 24, 8, 77, 16, 19),   # ragged T and V blocks
])
def test_cross_entropy_interpret_vs_ref(B, T, D, V, bt, bv):
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    h = jax.random.normal(ks[0], (B, T, D))
    w = jax.random.normal(ks[1], (D, V)) * 0.2
    lbl = jax.random.randint(ks[2], (B, T), 0, V)
    lbl = lbl.at[0, :3].set(-100)    # masked positions

    def f(mode, h_, w_):
        return ops.cross_entropy(h_, w_, lbl, block_t=bt, block_v=bv,
                                 force=mode)[0]

    loss_i, n_i = ops.cross_entropy(h, w, lbl, block_t=bt, block_v=bv,
                                    force="interpret")
    loss_r, n_r = ops.cross_entropy(h, w, lbl, force="ref")
    assert int(n_i) == int(n_r)
    _allclose(loss_i, loss_r, "ce loss", atol=1e-5, rtol=1e-5)
    _grad_parity(f, (h, w), atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------ vjp shape
def test_custom_vjp_grad_shapes_match_inputs():
    """The chunked-recompute backwards must return cotangents shaped
    exactly like their primals (a transposed or concat-misordered grad
    would train silently wrong)."""
    ks = jax.random.split(jax.random.PRNGKey(14), 5)
    B, T, H, D = 1, 48, 2, 16
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.random.normal(ks[3], (B, T, H, D)) * 0.3
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    grads = jax.grad(
        lambda *a: ops.rwkv6(*a, block_t=16, force="interpret")[0].sum(),
        argnums=(0, 1, 2, 3, 4))(r, k, v, w, u)
    for g, p in zip(grads, (r, k, v, w, u)):
        assert g.shape == p.shape and g.dtype == p.dtype


# ------------------------------------------------------------------ hypothesis
if HAVE_HYPOTHESIS:
    settings.register_profile("kernel_diff", max_examples=10, deadline=None)
    settings.load_profile("kernel_diff")

    @given(st.integers(0, 2 ** 16), st.integers(8, 96), st.integers(1, 3),
           st.booleans())
    def test_rwkv6_forward_property(seed, T, H, ragged):
        """Any (seed, T, H): interpret == ref for the WKV scan, including
        block-ragged tails."""
        D, bt = 16, 16
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        shape = (1, T, H, D)
        r, k, v = (jax.random.normal(ks[i], shape) for i in range(3))
        w = jax.random.normal(ks[3], shape) * 0.3
        u = jax.random.normal(ks[4], (H, D)) * 0.1
        y_i, s_i = ops.rwkv6(r, k, v, w, u, block_t=bt, force="interpret")
        y_r, s_r = ops.rwkv6(r, k, v, w, u, force="ref")
        _allclose(y_i, y_r, f"rwkv6 fwd seed={seed} T={T}", atol=2e-4,
                  rtol=2e-4)
        _allclose(s_i, s_r, f"rwkv6 state seed={seed} T={T}", atol=2e-4,
                  rtol=2e-4)

    @given(st.integers(0, 2 ** 16), st.integers(8, 96), st.integers(1, 3))
    def test_mamba2_forward_property(seed, T, H):
        P, N, bt = 16, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        x = jax.random.normal(ks[0], (1, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, T, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)))
        Bm = jax.random.normal(ks[3], (1, T, N))
        Cm = jax.random.normal(ks[4], (1, T, N))
        D = jax.random.normal(ks[5], (H,))
        y_i, h_i = ops.mamba2(x, dt, A, Bm, Cm, D, block_t=bt,
                              force="interpret")
        y_r, h_r = ops.mamba2(x, dt, A, Bm, Cm, D, force="ref")
        scale = max(float(jnp.abs(y_r).max()), 1.0)
        _allclose(y_i / scale, y_r / scale, f"mamba2 fwd seed={seed} T={T}",
                  atol=5e-5, rtol=5e-5)
        _allclose(h_i, h_r, f"mamba2 state seed={seed} T={T}", atol=2e-4,
                  rtol=1e-3)

    @given(st.integers(0, 2 ** 16), st.integers(4, 32), st.integers(17, 99))
    def test_cross_entropy_property(seed, T, V):
        """CE loss parity holds for any vocab size vs block_v=19 (prime —
        every non-divisible layout) and arbitrary mask patterns."""
        D = 8
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        h = jax.random.normal(ks[0], (1, T, D))
        w = jax.random.normal(ks[1], (D, V)) * 0.2
        lbl = jax.random.randint(ks[2], (1, T), 0, V)
        mask = jax.random.bernoulli(ks[3], 0.25, (1, T))
        lbl = jnp.where(mask, -100, lbl)
        loss_i, n_i = ops.cross_entropy(h, w, lbl, block_t=8, block_v=19,
                                        force="interpret")
        loss_r, n_r = ops.cross_entropy(h, w, lbl, force="ref")
        assert int(n_i) == int(n_r)
        if int(n_r) > 0:
            _allclose(loss_i, loss_r, f"ce seed={seed} T={T} V={V}",
                      atol=2e-5, rtol=2e-5)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_kernel_diff_property_layer():
        pass
