"""BlockRunner adapter contract, over all eight families.

The prefix cache leans on three adapter invariants that used to be
implicit: ``apply_units`` composes over contiguous ranges (incremental
advance = from-scratch prefix), ``merge`` splices EXACTLY [lo, hi) plus
the trained head/embed keys back into the full tree without mutating
its input, and ``merge(params, split(params))`` is the identity.  One
parametrized test asserts all of it for the ResNet / ViT / dense-LM /
Whisper adapters plus the sequence families on the Pallas fast path
(mamba2 / rwkv6 / zamba2 / moe — docs/sequence_models.md), so every
runner presents the same contract to ``core.blockwise.PrefixCache``.

The stateful-scan families also pin down the HONESTY of
``prefix_stable``: tied-embedding mamba2 and shared-attention zamba2
must report False (head updates leak into the prefix forward — the
re-buffering regression below), while untied rwkv6 genuinely is stable.

Also here: the regression test for the deleted dead branch in
``_whisper_runner.apply_units`` (``whisper.encode(...) if e_lo == 0 and
False else ...``): ``_enc_range`` is now the single encoder path, and
composing it over the full encoder must reproduce the reference
``whisper.encode`` — including the final encoder norm at the boundary.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.preresnet20 import reduced as rn_reduced
from repro.configs.vit_t16 import reduced as vit_reduced
from repro.core import blockwise
from repro.models import build, resnet, vit


def _resnet_setup(key):
    cfg = rn_reduced(num_classes=4, image_size=16)
    params = resnet.init(key, cfg)
    batch = {"images": jax.random.normal(jax.random.fold_in(key, 1),
                                         (4, 16, 16, 3)),
             "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                          (4,), 0, 4)}
    return blockwise.resnet_runner(cfg), params, batch


def _vit_setup(key):
    cfg = vit_reduced(num_classes=4)
    params = vit.init(key, cfg)
    batch = {"images": jax.random.normal(jax.random.fold_in(key, 1),
                                         (4, 16, 16, 3)),
             "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                          (4,), 0, 4)}
    return blockwise.vit_runner(cfg), params, batch


def _lm_setup(key):
    cfg = get_reduced_config("yi-6b")
    lm = build(cfg)
    params = lm.init(key)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    return (blockwise.lm_runner(lm, kernel_force="ref"), params,
            {"tokens": toks, "labels": toks})


def _whisper_setup(key):
    cfg = get_reduced_config("whisper-small")
    lm = build(cfg)
    params = lm.init(key)
    batch = {"encoder_embeds": jax.random.normal(key, (2, 16, cfg.d_model)),
             "tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    return blockwise.lm_runner(lm, kernel_force="ref"), params, batch


def _seq_setup(arch):
    def make(key):
        cfg = get_reduced_config(arch)
        lm = build(cfg)
        params = lm.init(key)
        toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
        return (blockwise.lm_runner(lm, kernel_force="ref"), params,
                {"tokens": toks, "labels": toks})
    return make


SETUPS = {"resnet": _resnet_setup, "vit": _vit_setup, "lm": _lm_setup,
          "whisper": _whisper_setup,
          "mamba2": _seq_setup("mamba2-370m"),
          "rwkv6": _seq_setup("rwkv6-7b"),
          "zamba2": _seq_setup("zamba2-1.2b"),
          "moe": _seq_setup("qwen3-moe-235b-a22b")}


def _leaves32(tree):
    return [jnp.asarray(x, jnp.float32) for x in jax.tree.leaves(tree)]


def _assert_trees_equal(a, b, msg, atol=0.0):
    la, lb = _leaves32(a), _leaves32(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=0, err_msg=msg)


@pytest.mark.parametrize("family", sorted(SETUPS))
def test_apply_units_composes_over_ranges(family):
    """apply_units(0, n) == apply_units(k, n) ∘ apply_units(0, k) — the
    invariant the prefix cache's incremental advance rests on."""
    runner, params, batch = SETUPS[family](jax.random.PRNGKey(0))
    n = runner.n_units
    k = n // 2
    z0 = runner.embed(params, batch)
    full = runner.apply_units(params, z0, 0, n)
    split_z = runner.apply_units(params, runner.apply_units(params, z0, 0, k),
                                 k, n)
    _assert_trees_equal(full, split_z, f"{family}: range composition",
                        atol=1e-5)


@pytest.mark.parametrize("family", sorted(SETUPS))
def test_split_merge_round_trip(family):
    runner, params, _ = SETUPS[family](jax.random.PRNGKey(1))
    n = runner.n_units
    for lo, hi in ((0, 1), (n // 2, n), (0, n)):
        train = runner.split(params, lo, hi)
        merged = runner.merge(params, train, lo=lo, hi=hi)
        _assert_trees_equal(params, merged,
                            f"{family}: merge(split) != identity "
                            f"for [{lo}, {hi})")


@pytest.mark.parametrize("family", sorted(SETUPS))
def test_merge_replaces_exactly_lo_hi(family):
    """Perturbing the trained subtree must change units [lo, hi) (and
    trained head/embed keys) and NOTHING else; the input params tree is
    never mutated."""
    runner, params, batch = SETUPS[family](jax.random.PRNGKey(2))
    n = runner.n_units
    lo, hi = (1, max(2, n // 2)) if n > 1 else (0, 1)
    before = jax.tree.map(lambda x: np.array(x), params)
    train = runner.split(params, lo, hi)
    bumped = jax.tree.map(lambda x: x + 1.0, train)
    merged = runner.merge(params, bumped, lo=lo, hi=hi)
    # the input tree is untouched
    _assert_trees_equal(params, before, f"{family}: merge mutated input")
    # the PREFIX UNITS [0, lo) are untouched by the merge (run from a
    # shared z0 so head-key effects on ``embed`` don't blur the check).
    # zamba2's shared attention + invocation norms are head keys that run
    # INSIDE every unit — restore them for the splice check and assert
    # their leak separately (it is the documented reason the hybrid
    # family reports prefix_stable=False)
    z0 = runner.embed(params, batch)
    merged_prefix = merged
    if family == "zamba2":
        merged_prefix = dict(merged)
        merged_prefix["shared"] = params["shared"]
        merged_prefix["invocation_norms"] = params["invocation_norms"]
    if lo > 0:
        _assert_trees_equal(
            runner.apply_units(params, z0, 0, lo),
            runner.apply_units(merged_prefix, z0, 0, lo),
            f"{family}: merge leaked into the [0, {lo}) prefix units",
            atol=1e-6)
    if family == "zamba2" and lo > 0:
        before_z = _leaves32(runner.apply_units(params, z0, 0, lo))
        after_z = _leaves32(runner.apply_units(merged, z0, 0, lo))
        assert any(float(jnp.abs(a - b).max()) > 0
                   for a, b in zip(before_z, after_z)), \
            "zamba2: shared-block head keys no longer reach the prefix " \
            "— prefix_stable may now be claimable as True"
    if runner.prefix_stable:
        # stable runners additionally promise the EMBED path never sees
        # head-trained keys — the full prefix forward is invariant, which
        # is what licenses PrefixCache's incremental advance
        _assert_trees_equal(
            runner.embed(params, batch), runner.embed(merged, batch),
            f"{family}: prefix_stable runner's embed saw trained keys")
    # the trained range really changed
    z_old = runner.apply_units(params, z0, lo, hi)
    z_new = runner.apply_units(merged, z0, lo, hi)
    diff = max(float(jnp.abs(a - b).max())
               for a, b in zip(_leaves32(z_old), _leaves32(z_new)))
    assert diff > 0, f"{family}: merge dropped the trained block"


@pytest.mark.parametrize("family,expect_stable", [
    ("mamba2", False),   # tied embeddings: head trains the embed table
    ("zamba2", False),   # hybrid: shared attention block trains with φ
    ("whisper", False),  # enc_norm / tied embed leak into the prefix
    ("rwkv6", True),     # untied: the prefix never sees head keys
    ("moe", True),
    ("lm", True),
])
def test_prefix_stable_is_honest(family, expect_stable):
    """``prefix_stable`` must MATCH the leak test in
    ``test_merge_replaces_exactly_lo_hi``: a runner claiming stability
    whose embed/prefix actually sees head-trained keys would make
    PrefixCache's incremental advance silently wrong."""
    runner, params, batch = SETUPS[family](jax.random.PRNGKey(7))
    assert runner.prefix_stable is expect_stable
    # direct leak probe: bump ONLY the head subtree (split over the last
    # unit excludes earlier layers) and watch the embed output
    n = runner.n_units
    train = runner.split(params, n - 1, n)
    bumped = jax.tree.map(lambda x: x + 1.0, train)
    merged = runner.merge(params, bumped, lo=n - 1, hi=n)
    emb_a = _leaves32(runner.embed(params, batch))
    emb_b = _leaves32(runner.embed(merged, batch))
    leaked = any(float(jnp.abs(a - b).max()) > 0
                 for a, b in zip(emb_a, emb_b))
    if expect_stable:
        assert not leaked, f"{family}: stable runner's embed leaked"
    elif family in ("mamba2", "whisper"):
        # the tied-embed families leak at the embed itself; zamba2 leaks
        # later (inside apply_units' shared block), asserted below
        assert leaked, f"{family}: expected tied-embed leak"


def test_unstable_families_rebuffer_per_subproblem():
    """Regression for the SSM/shared-attention families: with
    ``prefix_stable=False`` the PrefixCache must RE-BUFFER (prefix
    recompute once per subproblem) rather than incrementally advance a
    stale buffer — a stale z_{lo-1} would miss the head-trained keys
    that leak into the prefix forward."""
    for family in ("mamba2", "zamba2"):
        runner, params, batch = SETUPS[family](jax.random.PRNGKey(8))
        n = runner.n_units
        assert not runner.prefix_stable
        cache = blockwise.PrefixCache(runner)
        cache.prepare(params, [batch], 0)
        # train [0,1): the head (tied embed / shared attn) moves too
        train = runner.split(params, 0, 1)
        bumped = jax.tree.map(lambda x: x + 0.01, train)
        p2 = runner.merge(params, bumped, lo=0, hi=1)
        z = cache.prepare(p2, [batch], 1)[0]
        # the buffer equals a from-scratch prefix under the NEW params —
        # possible only if it re-buffered (advancing the old buffer
        # through units [0,1) would use the stale embed output)
        fresh = runner.apply_units(p2, runner.embed(p2, batch), 0, 1)
        # jit-vs-eager float noise only; a stale buffer misses a +0.01
        # head bump and differs by orders of magnitude more than 1e-4
        _assert_trees_equal(z, fresh,
                            f"{family}: stale buffer (no re-buffering)",
                            atol=1e-4)


def test_resnet_merge_preserves_block_list_structure():
    """The unified splice keeps ``blocks`` a plain list of per-block
    dicts (stages have different widths — no single stacked array)."""
    runner, params, _ = _resnet_setup(jax.random.PRNGKey(3))
    train = runner.split(params, 1, 2)
    merged = runner.merge(params, train, lo=1, hi=2)
    assert isinstance(merged["blocks"], list)
    assert len(merged["blocks"]) == len(params["blocks"])
    # untouched entries are the SAME objects (splice, not rebuild)
    assert merged["blocks"][0] is params["blocks"][0]


def test_whisper_enc_range_matches_reference_encoder():
    """Regression for the deleted dead branch: embed + apply_units over
    the full encoder range must equal ``whisper.encode`` on the raw
    frame embeddings (pos added once, final norm applied at hi == E)."""
    from repro.models import whisper
    cfg = get_reduced_config("whisper-small")
    lm = build(cfg)
    key = jax.random.PRNGKey(4)
    params = lm.init(key)
    batch = {"encoder_embeds": jax.random.normal(key, (2, 16, cfg.d_model)),
             "tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    runner = blockwise.lm_runner(lm, kernel_force="ref")
    E = cfg.encoder_layers
    z = runner.apply_units(params, runner.embed(params, batch), 0, E)
    ref = whisper.encode(params, cfg, batch["encoder_embeds"],
                         kernel_force="ref")
    np.testing.assert_allclose(
        np.asarray(z["enc"], np.float32), np.asarray(ref, np.float32),
        atol=1e-5, rtol=1e-5)
    # and split ranges compose to the same thing (the single _enc_range
    # path handles interior slices without the final norm)
    z_half = runner.apply_units(params, runner.embed(params, batch), 0, E // 2)
    z_rest = runner.apply_units(params, z_half, E // 2, E)
    np.testing.assert_allclose(
        np.asarray(z_rest["enc"], np.float32), np.asarray(ref, np.float32),
        atol=1e-5, rtol=1e-5)
