"""BlockRunner adapter contract, over all four families.

The prefix cache leans on three adapter invariants that used to be
implicit: ``apply_units`` composes over contiguous ranges (incremental
advance = from-scratch prefix), ``merge`` splices EXACTLY [lo, hi) plus
the trained head/embed keys back into the full tree without mutating
its input, and ``merge(params, split(params))`` is the identity.  One
parametrized test asserts all of it for the ResNet / ViT / LM / Whisper
adapters, so every runner presents the same contract to
``core.blockwise.PrefixCache``.

Also here: the regression test for the deleted dead branch in
``_whisper_runner.apply_units`` (``whisper.encode(...) if e_lo == 0 and
False else ...``): ``_enc_range`` is now the single encoder path, and
composing it over the full encoder must reproduce the reference
``whisper.encode`` — including the final encoder norm at the boundary.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.preresnet20 import reduced as rn_reduced
from repro.configs.vit_t16 import reduced as vit_reduced
from repro.core import blockwise
from repro.models import build, resnet, vit


def _resnet_setup(key):
    cfg = rn_reduced(num_classes=4, image_size=16)
    params = resnet.init(key, cfg)
    batch = {"images": jax.random.normal(jax.random.fold_in(key, 1),
                                         (4, 16, 16, 3)),
             "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                          (4,), 0, 4)}
    return blockwise.resnet_runner(cfg), params, batch


def _vit_setup(key):
    cfg = vit_reduced(num_classes=4)
    params = vit.init(key, cfg)
    batch = {"images": jax.random.normal(jax.random.fold_in(key, 1),
                                         (4, 16, 16, 3)),
             "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                          (4,), 0, 4)}
    return blockwise.vit_runner(cfg), params, batch


def _lm_setup(key):
    cfg = get_reduced_config("yi-6b")
    lm = build(cfg)
    params = lm.init(key)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    return (blockwise.lm_runner(lm, kernel_force="ref"), params,
            {"tokens": toks, "labels": toks})


def _whisper_setup(key):
    cfg = get_reduced_config("whisper-small")
    lm = build(cfg)
    params = lm.init(key)
    batch = {"encoder_embeds": jax.random.normal(key, (2, 16, cfg.d_model)),
             "tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    return blockwise.lm_runner(lm, kernel_force="ref"), params, batch


SETUPS = {"resnet": _resnet_setup, "vit": _vit_setup, "lm": _lm_setup,
          "whisper": _whisper_setup}


def _leaves32(tree):
    return [jnp.asarray(x, jnp.float32) for x in jax.tree.leaves(tree)]


def _assert_trees_equal(a, b, msg, atol=0.0):
    la, lb = _leaves32(a), _leaves32(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=0, err_msg=msg)


@pytest.mark.parametrize("family", sorted(SETUPS))
def test_apply_units_composes_over_ranges(family):
    """apply_units(0, n) == apply_units(k, n) ∘ apply_units(0, k) — the
    invariant the prefix cache's incremental advance rests on."""
    runner, params, batch = SETUPS[family](jax.random.PRNGKey(0))
    n = runner.n_units
    k = n // 2
    z0 = runner.embed(params, batch)
    full = runner.apply_units(params, z0, 0, n)
    split_z = runner.apply_units(params, runner.apply_units(params, z0, 0, k),
                                 k, n)
    _assert_trees_equal(full, split_z, f"{family}: range composition",
                        atol=1e-5)


@pytest.mark.parametrize("family", sorted(SETUPS))
def test_split_merge_round_trip(family):
    runner, params, _ = SETUPS[family](jax.random.PRNGKey(1))
    n = runner.n_units
    for lo, hi in ((0, 1), (n // 2, n), (0, n)):
        train = runner.split(params, lo, hi)
        merged = runner.merge(params, train, lo=lo, hi=hi)
        _assert_trees_equal(params, merged,
                            f"{family}: merge(split) != identity "
                            f"for [{lo}, {hi})")


@pytest.mark.parametrize("family", sorted(SETUPS))
def test_merge_replaces_exactly_lo_hi(family):
    """Perturbing the trained subtree must change units [lo, hi) (and
    trained head/embed keys) and NOTHING else; the input params tree is
    never mutated."""
    runner, params, batch = SETUPS[family](jax.random.PRNGKey(2))
    n = runner.n_units
    lo, hi = (1, max(2, n // 2)) if n > 1 else (0, 1)
    before = jax.tree.map(lambda x: np.array(x), params)
    train = runner.split(params, lo, hi)
    bumped = jax.tree.map(lambda x: x + 1.0, train)
    merged = runner.merge(params, bumped, lo=lo, hi=hi)
    # the input tree is untouched
    _assert_trees_equal(params, before, f"{family}: merge mutated input")
    # the PREFIX UNITS [0, lo) are untouched by the merge (run from a
    # shared z0 so head-key effects on ``embed`` don't blur the check)
    z0 = runner.embed(params, batch)
    if lo > 0:
        _assert_trees_equal(
            runner.apply_units(params, z0, 0, lo),
            runner.apply_units(merged, z0, 0, lo),
            f"{family}: merge leaked into the [0, {lo}) prefix units",
            atol=1e-6)
    if runner.prefix_stable:
        # stable runners additionally promise the EMBED path never sees
        # head-trained keys — the full prefix forward is invariant, which
        # is what licenses PrefixCache's incremental advance
        _assert_trees_equal(
            runner.embed(params, batch), runner.embed(merged, batch),
            f"{family}: prefix_stable runner's embed saw trained keys")
    # the trained range really changed
    z_old = runner.apply_units(params, z0, lo, hi)
    z_new = runner.apply_units(merged, z0, lo, hi)
    diff = max(float(jnp.abs(a - b).max())
               for a, b in zip(_leaves32(z_old), _leaves32(z_new)))
    assert diff > 0, f"{family}: merge dropped the trained block"


def test_resnet_merge_preserves_block_list_structure():
    """The unified splice keeps ``blocks`` a plain list of per-block
    dicts (stages have different widths — no single stacked array)."""
    runner, params, _ = _resnet_setup(jax.random.PRNGKey(3))
    train = runner.split(params, 1, 2)
    merged = runner.merge(params, train, lo=1, hi=2)
    assert isinstance(merged["blocks"], list)
    assert len(merged["blocks"]) == len(params["blocks"])
    # untouched entries are the SAME objects (splice, not rebuild)
    assert merged["blocks"][0] is params["blocks"][0]


def test_whisper_enc_range_matches_reference_encoder():
    """Regression for the deleted dead branch: embed + apply_units over
    the full encoder range must equal ``whisper.encode`` on the raw
    frame embeddings (pos added once, final norm applied at hi == E)."""
    from repro.models import whisper
    cfg = get_reduced_config("whisper-small")
    lm = build(cfg)
    key = jax.random.PRNGKey(4)
    params = lm.init(key)
    batch = {"encoder_embeds": jax.random.normal(key, (2, 16, cfg.d_model)),
             "tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    runner = blockwise.lm_runner(lm, kernel_force="ref")
    E = cfg.encoder_layers
    z = runner.apply_units(params, runner.embed(params, batch), 0, E)
    ref = whisper.encode(params, cfg, batch["encoder_embeds"],
                         kernel_force="ref")
    np.testing.assert_allclose(
        np.asarray(z["enc"], np.float32), np.asarray(ref, np.float32),
        atol=1e-5, rtol=1e-5)
    # and split ranges compose to the same thing (the single _enc_range
    # path handles interior slices without the final norm)
    z_half = runner.apply_units(params, runner.embed(params, batch), 0, E // 2)
    z_rest = runner.apply_units(params, z_half, E // 2, E)
    np.testing.assert_allclose(
        np.asarray(z_rest["enc"], np.float32), np.asarray(ref, np.float32),
        atol=1e-5, rtol=1e-5)
